//! Multi-tenant QoS subsystem: SLO classes, deadline-aware queueing, and
//! admission control under overload.
//!
//! The paper (and the seed) treats all tasks as one undifferentiated
//! stream. Real AIGC serving is multi-tenant: a premium tenant buys a
//! tight latency SLO and a high quality floor, a batch tenant tolerates
//! hours, and under overload the scheduler must decide *whose* tasks wait
//! or get shed. This module adds that axis:
//!
//! - [`TenantConfig`] / [`TenantsConfig`] — per-tenant SLO classes
//!   (latency deadline budget, quality floor `q_min`, weight, priority
//!   tier) with their own arrival processes, serialised inside
//!   `EnvConfig` (JSON round-trip).
//! - [`TenantRegistry`] — the resolved runtime registry: tier slots,
//!   per-tier weights, tenant lookups.
//! - [`queue`] — [`queue::EdfWfqQueue`] / [`queue::PendingQueue`]:
//!   earliest-deadline-first within a tier, smooth weighted round robin
//!   across tiers, replacing the env's FIFO pending queue.
//! - [`admission`] — [`AdmissionConfig`] / [`AdmissionState`]: admit-all,
//!   bounded-queue drop-tail, and per-tenant token buckets that shed load
//!   under sustained overload instead of queueing forever.
//! - [`generate_workload`] — per-tenant arrival processes composed from
//!   `workload::ArrivalProcess` / `TaskMix`, merged into one deterministic
//!   task stream (tasks carry `tenant` + absolute `deadline`).
//!
//! `eat qos` (`experiments::qos`) sweeps overload factors × admission
//! policies × queue disciplines and reports per-tenant p50/p90/p99, SLO
//! attainment, and drop rates.

pub mod admission;
pub mod queue;

pub use admission::{AdmissionConfig, AdmissionState};
pub use queue::{EdfWfqQueue, PendingQueue};

use crate::config::EnvConfig;
use crate::sim::task::{Task, Workload};
use crate::util::json::Value;
use crate::util::rng::Pcg64;
use crate::workload::{
    model_mix_from_json, model_mix_to_json, ArrivalConfig, ModelMix, QualityDemand, TaskMix,
};

/// Which discipline orders the pending queue.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QueueDiscipline {
    /// Arrival order (the seed behaviour).
    Fifo,
    /// Earliest-deadline-first within a tier, weighted-fair across tiers.
    EdfWfq,
}

impl QueueDiscipline {
    pub fn name(&self) -> &'static str {
        match self {
            QueueDiscipline::Fifo => "fifo",
            QueueDiscipline::EdfWfq => "edf",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<QueueDiscipline> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "fifo" => QueueDiscipline::Fifo,
            "edf" | "edf-wfq" | "edfwfq" | "qos" => QueueDiscipline::EdfWfq,
            other => anyhow::bail!("unknown queue discipline '{other}' (fifo, edf)"),
        })
    }
}

/// One tenant's SLO class and traffic description.
#[derive(Clone, Debug, PartialEq)]
pub struct TenantConfig {
    pub name: String,
    /// Priority tier; lower tiers are ordered first in the queue's
    /// round-robin (tiers compete by weight, they do not strictly preempt).
    pub tier: u8,
    /// Service weight: a backlogged tier's share of dequeues is its
    /// tenants' total weight over the backlogged total.
    pub weight: f64,
    /// Latency SLO budget (s): a task meets its SLO iff response time
    /// (waiting + execution) stays within this budget of its arrival.
    pub latency_slo: f64,
    /// SLO attainment target in (0, 1): the fraction of outcomes that
    /// must meet the latency SLO. Defines the tenant's error budget for
    /// `eat slo report` — `(1 - slo_target) × outcomes` misses allowed.
    pub slo_target: f64,
    /// Per-task quality floor; becomes each task's `q_min`.
    pub q_min: f64,
    /// This tenant's own arrival process.
    pub arrival: ArrivalConfig,
    /// Model popularity within this tenant's traffic.
    pub model_mix: ModelMix,
}

impl TenantConfig {
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(!self.name.is_empty(), "tenant name must be non-empty");
        anyhow::ensure!(
            self.weight > 0.0 && self.weight.is_finite(),
            "tenant '{}' weight must be > 0",
            self.name
        );
        anyhow::ensure!(
            self.latency_slo > 0.0 && self.latency_slo.is_finite(),
            "tenant '{}' latency_slo must be > 0",
            self.name
        );
        anyhow::ensure!(
            self.q_min > 0.0 && self.q_min.is_finite(),
            "tenant '{}' q_min must be > 0",
            self.name
        );
        anyhow::ensure!(
            self.slo_target > 0.0 && self.slo_target < 1.0,
            "tenant '{}' slo_target must be in (0, 1)",
            self.name
        );
        self.arrival.validate()
    }

    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("name", self.name.as_str())
            .set("tier", self.tier as usize)
            .set("weight", self.weight)
            .set("latency_slo", self.latency_slo)
            .set("slo_target", self.slo_target)
            .set("q_min", self.q_min)
            .set("arrival", self.arrival.to_json());
        if self.model_mix != ModelMix::Uniform {
            v.set("model_mix", model_mix_to_json(&self.model_mix));
        }
        v
    }

    pub fn from_json(v: &Value) -> anyhow::Result<TenantConfig> {
        let num = |key: &str| -> anyhow::Result<f64> {
            v.req(key)?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("tenant field '{key}' is not a number"))
        };
        let cfg = TenantConfig {
            name: v
                .req("name")?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("tenant 'name' must be a string"))?
                .to_string(),
            tier: num("tier")? as u8,
            weight: num("weight")?,
            latency_slo: num("latency_slo")?,
            // Pre-PR-8 configs carry no target; 0.9 is the conventional
            // "one nine" default.
            slo_target: v.get("slo_target").and_then(Value::as_f64).unwrap_or(0.9),
            q_min: num("q_min")?,
            arrival: ArrivalConfig::from_json(v.req("arrival")?)?,
            model_mix: match v.get("model_mix") {
                Some(m) => model_mix_from_json(m)?,
                None => ModelMix::Uniform,
            },
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

/// The complete multi-tenant section of an env config.
#[derive(Clone, Debug, PartialEq)]
pub struct TenantsConfig {
    pub tenants: Vec<TenantConfig>,
    pub admission: AdmissionConfig,
    pub queue: QueueDiscipline,
}

impl TenantsConfig {
    /// Three-class preset — premium / standard / batch sharing `total_rate`
    /// equally as demand but weighted 6:3:1 for service. Equal SLO budgets
    /// make SLO attainment a pure function of service share, so under
    /// overload the attainment ordering must follow the weights.
    pub fn three_tier(total_rate: f64) -> TenantsConfig {
        let lane = total_rate / 3.0;
        let tenant = |name: &str, tier: u8, weight: f64, q_min: f64, slo_target: f64| TenantConfig {
            name: name.to_string(),
            tier,
            weight,
            latency_slo: 120.0,
            slo_target,
            q_min,
            arrival: ArrivalConfig::Poisson { rate: lane },
            model_mix: ModelMix::Uniform,
        };
        TenantsConfig {
            tenants: vec![
                tenant("premium", 0, 6.0, 0.24, 0.9),
                tenant("standard", 1, 3.0, 0.22, 0.75),
                tenant("batch", 2, 1.0, 0.20, 0.5),
            ],
            admission: AdmissionConfig::AdmitAll,
            queue: QueueDiscipline::EdfWfq,
        }
    }

    /// Scale every tenant's arrival rate by `factor` (overload sweeps).
    pub fn scaled(&self, factor: f64) -> TenantsConfig {
        let mut out = self.clone();
        for t in &mut out.tenants {
            t.arrival = t.arrival.scaled(factor);
        }
        out
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(!self.tenants.is_empty(), "tenants section must list at least one tenant");
        for t in &self.tenants {
            t.validate()?;
        }
        for (i, a) in self.tenants.iter().enumerate() {
            for b in &self.tenants[i + 1..] {
                anyhow::ensure!(a.name != b.name, "duplicate tenant name '{}'", a.name);
            }
        }
        self.admission.validate()
    }

    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set(
            "tenants",
            Value::Arr(self.tenants.iter().map(TenantConfig::to_json).collect()),
        );
        v.set("admission", self.admission.to_json());
        v.set("queue", self.queue.name());
        v
    }

    pub fn from_json(v: &Value) -> anyhow::Result<TenantsConfig> {
        let tenants = v
            .req("tenants")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("'tenants' must be an array"))?
            .iter()
            .map(TenantConfig::from_json)
            .collect::<anyhow::Result<Vec<_>>>()?;
        let cfg = TenantsConfig {
            tenants,
            admission: match v.get("admission") {
                Some(a) => AdmissionConfig::from_json(a)?,
                None => AdmissionConfig::AdmitAll,
            },
            queue: match v.get("queue").and_then(Value::as_str) {
                Some(s) => QueueDiscipline::parse(s)?,
                None => QueueDiscipline::EdfWfq,
            },
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

/// Resolved runtime registry: tier slots in priority order plus a trailing
/// fallback slot for untenanted tasks.
#[derive(Clone, Debug)]
pub struct TenantRegistry {
    cfg: TenantsConfig,
    tiers: Vec<u8>,
    /// Per-slot service weights: one slot per distinct tier (ascending)
    /// plus a final weight-1 fallback slot for untenanted tasks.
    queue_weights: Vec<f64>,
    tenant_slot: Vec<usize>,
}

impl TenantRegistry {
    pub fn new(cfg: &TenantsConfig) -> TenantRegistry {
        let mut tiers: Vec<u8> = cfg.tenants.iter().map(|t| t.tier).collect();
        tiers.sort_unstable();
        tiers.dedup();
        let mut queue_weights = vec![0.0; tiers.len() + 1];
        let mut tenant_slot = Vec::with_capacity(cfg.tenants.len());
        for t in &cfg.tenants {
            let slot = tiers.binary_search(&t.tier).expect("tier present");
            queue_weights[slot] += t.weight;
            tenant_slot.push(slot);
        }
        let last = queue_weights.len() - 1;
        queue_weights[last] = 1.0;
        TenantRegistry {
            cfg: cfg.clone(),
            tiers,
            queue_weights,
            tenant_slot,
        }
    }

    pub fn config(&self) -> &TenantsConfig {
        &self.cfg
    }

    pub fn num_tenants(&self) -> usize {
        self.cfg.tenants.len()
    }

    pub fn tenant(&self, i: usize) -> &TenantConfig {
        &self.cfg.tenants[i]
    }

    /// Tenant name, or "untenanted" for ids outside the registry.
    pub fn name(&self, tenant: Option<u32>) -> &str {
        tenant
            .and_then(|t| self.cfg.tenants.get(t as usize))
            .map_or("untenanted", |t| t.name.as_str())
    }

    /// Service weight of a task's tenant (1.0 when untenanted/unknown).
    pub fn weight(&self, tenant: Option<u32>) -> f64 {
        tenant
            .and_then(|t| self.cfg.tenants.get(t as usize))
            .map_or(1.0, |t| t.weight)
    }

    /// Queue slot for a task: its tenant's tier slot, or the fallback.
    pub fn tier_slot(&self, tenant: Option<u32>) -> usize {
        let fallback = self.queue_weights.len() - 1;
        tenant
            .and_then(|t| self.tenant_slot.get(t as usize).copied())
            .unwrap_or(fallback)
    }

    /// Per-slot weights for [`queue::EdfWfqQueue`] (fallback slot last).
    pub fn queue_weights(&self) -> &[f64] {
        &self.queue_weights
    }

    pub fn num_tiers(&self) -> usize {
        self.tiers.len()
    }
}

/// Generate a multi-tenant workload: one arrival process + mix per tenant
/// (each on a forked RNG stream, so lanes are independent yet the whole
/// workload is a deterministic function of the seed), merged globally by
/// arrival time. Each task carries its tenant id, the tenant's quality
/// floor, and an absolute deadline `arrival + latency_slo`.
pub fn generate_workload(
    env: &EnvConfig,
    reg: &TenantRegistry,
    n: usize,
    rng: &mut Pcg64,
) -> Workload {
    struct Lane {
        arrival: Box<dyn crate::workload::ArrivalProcess>,
        mix: TaskMix,
        rng: Pcg64,
        clock: f64,
        pending: Option<(f64, crate::workload::MixSample, u64)>,
    }
    let mut lanes: Vec<Lane> = (0..reg.num_tenants())
        .map(|i| {
            let t = reg.tenant(i);
            Lane {
                arrival: t.arrival.build(),
                mix: TaskMix::new(env, t.model_mix.clone(), QualityDemand::Default),
                rng: rng.fork(100 + i as u64),
                clock: 0.0,
                pending: None,
            }
        })
        .collect();
    if lanes.is_empty() {
        return Workload { tasks: Vec::new() };
    }
    let mut tasks = Vec::with_capacity(n);
    for id in 0..n as u64 {
        for lane in lanes.iter_mut() {
            if lane.pending.is_none() {
                let t = lane.arrival.next_after(lane.clock, &mut lane.rng);
                lane.clock = t;
                let s = lane.mix.sample(t, &mut lane.rng);
                let prompt = lane.rng.next_u64();
                lane.pending = Some((t, s, prompt));
            }
        }
        let mut best = 0usize;
        let mut best_t = lanes[0].pending.as_ref().expect("refilled").0;
        for (i, lane) in lanes.iter().enumerate().skip(1) {
            let t = lane.pending.as_ref().expect("refilled").0;
            if t < best_t {
                best = i;
                best_t = t;
            }
        }
        let (arrival, sample, prompt_id) = lanes[best].pending.take().expect("refilled");
        let tc = reg.tenant(best);
        tasks.push(Task {
            id,
            prompt_id,
            patches: sample.patches,
            model: sample.model,
            arrival,
            q_min: Some(tc.q_min),
            tenant: Some(best as u32),
            deadline: Some(arrival + tc.latency_slo),
        });
    }
    Workload { tasks }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EnvConfig;

    #[test]
    fn three_tier_preset_validates_and_scales() {
        let cfg = TenantsConfig::three_tier(0.3);
        cfg.validate().unwrap();
        assert_eq!(cfg.tenants.len(), 3);
        let scaled = cfg.scaled(2.0);
        for (a, b) in cfg.tenants.iter().zip(&scaled.tenants) {
            let (ArrivalConfig::Poisson { rate: ra }, ArrivalConfig::Poisson { rate: rb }) =
                (&a.arrival, &b.arrival)
            else {
                panic!("preset lanes are Poisson");
            };
            assert!((rb - ra * 2.0).abs() < 1e-12);
        }
    }

    #[test]
    fn registry_maps_tiers_weights_and_fallback() {
        let reg = TenantRegistry::new(&TenantsConfig::three_tier(0.3));
        assert_eq!(reg.num_tenants(), 3);
        assert_eq!(reg.num_tiers(), 3);
        // Slots 0..2 for tiers 0..2, slot 3 is the fallback.
        assert_eq!(reg.queue_weights(), &[6.0, 3.0, 1.0, 1.0]);
        assert_eq!(reg.tier_slot(Some(0)), 0);
        assert_eq!(reg.tier_slot(Some(2)), 2);
        assert_eq!(reg.tier_slot(None), 3);
        assert_eq!(reg.tier_slot(Some(99)), 3);
        assert_eq!(reg.weight(Some(0)), 6.0);
        assert_eq!(reg.weight(None), 1.0);
        assert_eq!(reg.name(Some(1)), "standard");
        assert_eq!(reg.name(None), "untenanted");
    }

    #[test]
    fn shared_tier_weights_accumulate() {
        let mut cfg = TenantsConfig::three_tier(0.3);
        cfg.tenants[1].tier = 0; // standard joins premium's tier
        let reg = TenantRegistry::new(&cfg);
        assert_eq!(reg.num_tiers(), 2);
        assert_eq!(reg.queue_weights(), &[9.0, 1.0, 1.0]);
        assert_eq!(reg.tier_slot(Some(1)), 0);
        assert_eq!(reg.tier_slot(Some(2)), 1);
    }

    #[test]
    fn tenant_workload_is_sorted_tagged_and_deterministic() {
        let env = EnvConfig::default();
        let cfg = TenantsConfig::three_tier(0.3);
        let reg = TenantRegistry::new(&cfg);
        let a = generate_workload(&env, &reg, 200, &mut Pcg64::seeded(11));
        let b = generate_workload(&env, &reg, 200, &mut Pcg64::seeded(11));
        assert_eq!(a.len(), 200);
        assert!(a.is_sorted());
        let mut seen = vec![0usize; 3];
        for (x, y) in a.tasks.iter().zip(&b.tasks) {
            assert_eq!(x.arrival.to_bits(), y.arrival.to_bits());
            assert_eq!(x.tenant, y.tenant);
            assert_eq!(x.prompt_id, y.prompt_id);
            let tenant = x.tenant.expect("tagged") as usize;
            seen[tenant] += 1;
            let tc = reg.tenant(tenant);
            assert_eq!(x.q_min, Some(tc.q_min));
            let d = x.deadline.expect("deadline set");
            assert!((d - (x.arrival + tc.latency_slo)).abs() < 1e-9);
        }
        // Equal lane rates: every tenant contributes a healthy share.
        for (i, &n) in seen.iter().enumerate() {
            assert!(n > 30, "tenant {i} produced only {n}/200 tasks");
        }
    }

    #[test]
    fn tenants_config_json_roundtrip_with_all_admissions() {
        for admission in [
            AdmissionConfig::AdmitAll,
            AdmissionConfig::DropTail { max_queue: 24 },
            AdmissionConfig::TokenBucket { rate: 0.2, burst: 6.0 },
        ] {
            let mut cfg = TenantsConfig::three_tier(0.21);
            cfg.admission = admission;
            cfg.queue = QueueDiscipline::Fifo;
            let back = TenantsConfig::from_json(&cfg.to_json()).unwrap();
            assert_eq!(back, cfg);
        }
    }

    #[test]
    fn invalid_tenants_rejected() {
        let mut cfg = TenantsConfig::three_tier(0.3);
        cfg.tenants[0].weight = 0.0;
        assert!(cfg.validate().is_err());
        let mut cfg = TenantsConfig::three_tier(0.3);
        cfg.tenants[1].name = "premium".into();
        assert!(cfg.validate().is_err());
        let mut cfg = TenantsConfig::three_tier(0.3);
        cfg.tenants.clear();
        assert!(cfg.validate().is_err());
        let mut cfg = TenantsConfig::three_tier(0.3);
        cfg.tenants[2].latency_slo = -1.0;
        assert!(cfg.validate().is_err());
        let mut cfg = TenantsConfig::three_tier(0.3);
        cfg.tenants[0].slo_target = 1.0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn slo_target_defaults_and_round_trips() {
        let cfg = TenantsConfig::three_tier(0.3);
        let targets: Vec<f64> = cfg.tenants.iter().map(|t| t.slo_target).collect();
        assert_eq!(targets, vec![0.9, 0.75, 0.5]);
        // A pre-slo_target config document parses with the 0.9 default.
        let mut doc = cfg.to_json();
        let Value::Obj(ref mut map) = doc else { panic!("object") };
        let Some(Value::Arr(tenants)) = map.get_mut("tenants") else { panic!("array") };
        for t in tenants.iter_mut() {
            let Value::Obj(ref mut tm) = t else { panic!("object") };
            tm.remove("slo_target");
        }
        let back = TenantsConfig::from_json(&doc).unwrap();
        assert!(back.tenants.iter().all(|t| t.slo_target == 0.9));
    }
}
