//! Admission control: what happens when demand outruns capacity.
//!
//! The seed queued every arrival forever — under sustained overload the
//! pending queue (and every latency percentile) grows without bound, which
//! is exactly the regime where real serving systems shed load instead.
//! Three pluggable controllers:
//!
//! - [`AdmissionConfig::AdmitAll`] — the seed behaviour (and the default).
//! - [`AdmissionConfig::DropTail`] — bounded pending queue: arrivals past
//!   `max_queue` waiting tasks are rejected at the door.
//! - [`AdmissionConfig::TokenBucket`] — per-tenant rate limiting: each
//!   tenant owns a bucket refilling at its weighted share of the
//!   configured rate, so overload is shed proportionally to entitlement
//!   rather than first-come-first-served.
//!
//! Decisions are a pure function of (config, arrival time, queue length,
//! bucket state), so admission replays bit-identically with the episode.

use super::TenantRegistry;
use crate::util::json::Value;

/// Serialisable admission-controller description.
#[derive(Clone, Debug, PartialEq)]
pub enum AdmissionConfig {
    /// Admit every arrival (unbounded queue; the seed behaviour).
    AdmitAll,
    /// Reject arrivals while `max_queue` tasks are already waiting.
    DropTail { max_queue: usize },
    /// Per-tenant token buckets: tokens refill at `rate` × the tenant's
    /// weight share (tokens/s) up to `burst` × share; one token per task.
    /// Without a tenant registry a single global bucket applies.
    TokenBucket { rate: f64, burst: f64 },
}

impl AdmissionConfig {
    pub fn name(&self) -> &'static str {
        match self {
            AdmissionConfig::AdmitAll => "admit-all",
            AdmissionConfig::DropTail { .. } => "drop-tail",
            AdmissionConfig::TokenBucket { .. } => "token-bucket",
        }
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        match *self {
            AdmissionConfig::AdmitAll => Ok(()),
            AdmissionConfig::DropTail { max_queue } => {
                anyhow::ensure!(max_queue >= 1, "drop-tail max_queue must be >= 1");
                Ok(())
            }
            AdmissionConfig::TokenBucket { rate, burst } => {
                anyhow::ensure!(
                    rate > 0.0 && rate.is_finite(),
                    "token-bucket rate must be > 0, got {rate}"
                );
                anyhow::ensure!(
                    burst >= 1.0 && burst.is_finite(),
                    "token-bucket burst must be >= 1, got {burst}"
                );
                Ok(())
            }
        }
    }

    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        match *self {
            AdmissionConfig::AdmitAll => {
                v.set("kind", "admit_all");
            }
            AdmissionConfig::DropTail { max_queue } => {
                v.set("kind", "drop_tail").set("max_queue", max_queue);
            }
            AdmissionConfig::TokenBucket { rate, burst } => {
                v.set("kind", "token_bucket").set("rate", rate).set("burst", burst);
            }
        }
        v
    }

    pub fn from_json(v: &Value) -> anyhow::Result<AdmissionConfig> {
        let kind = v
            .req("kind")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("admission 'kind' must be a string"))?;
        let cfg = match kind {
            "admit_all" => AdmissionConfig::AdmitAll,
            "drop_tail" => AdmissionConfig::DropTail {
                max_queue: v
                    .req("max_queue")?
                    .as_usize()
                    .ok_or_else(|| anyhow::anyhow!("drop_tail max_queue must be a number"))?,
            },
            "token_bucket" => AdmissionConfig::TokenBucket {
                rate: v
                    .req("rate")?
                    .as_f64()
                    .ok_or_else(|| anyhow::anyhow!("token_bucket rate must be a number"))?,
                burst: v
                    .req("burst")?
                    .as_f64()
                    .ok_or_else(|| anyhow::anyhow!("token_bucket burst must be a number"))?,
            },
            other => anyhow::bail!("unknown admission kind '{other}'"),
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

#[derive(Clone, Debug)]
struct Bucket {
    tokens: f64,
    rate: f64,
    burst: f64,
    last: f64,
}

impl Bucket {
    fn take(&mut self, now: f64) -> bool {
        self.tokens = (self.tokens + (now - self.last).max(0.0) * self.rate).min(self.burst);
        self.last = now;
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

/// Runtime admission state: the config plus per-tenant token buckets.
/// `Clone` keeps planning rollouts (Harmony/Genetic clone the env) exact.
#[derive(Clone, Debug)]
pub struct AdmissionState {
    cfg: AdmissionConfig,
    buckets: Vec<Bucket>,
    /// True when buckets are indexed by tenant id (registry mode); false
    /// when a single global bucket rate-limits every arrival.
    per_tenant: bool,
}

impl AdmissionState {
    pub fn new(cfg: AdmissionConfig, registry: Option<&TenantRegistry>) -> AdmissionState {
        let (buckets, per_tenant) = match (&cfg, registry) {
            (AdmissionConfig::TokenBucket { rate, burst }, Some(reg)) => {
                let total: f64 = (0..reg.num_tenants()).map(|i| reg.tenant(i).weight).sum();
                let buckets = (0..reg.num_tenants())
                    .map(|i| {
                        let share = reg.tenant(i).weight / total.max(1e-12);
                        let cap = (burst * share).max(1.0);
                        Bucket {
                            tokens: cap,
                            rate: rate * share,
                            burst: cap,
                            last: 0.0,
                        }
                    })
                    .collect();
                (buckets, true)
            }
            (AdmissionConfig::TokenBucket { rate, burst }, None) => (
                vec![Bucket {
                    tokens: *burst,
                    rate: *rate,
                    burst: *burst,
                    last: 0.0,
                }],
                false,
            ),
            _ => (Vec::new(), false),
        };
        AdmissionState {
            cfg,
            buckets,
            per_tenant,
        }
    }

    pub fn config(&self) -> &AdmissionConfig {
        &self.cfg
    }

    /// Decide one arrival: `true` admits the task into the pending queue.
    /// `now` must be non-decreasing across calls (the env guarantees it).
    pub fn admit(&mut self, tenant: Option<u32>, now: f64, queue_len: usize) -> bool {
        match &self.cfg {
            AdmissionConfig::AdmitAll => true,
            AdmissionConfig::DropTail { max_queue } => queue_len < *max_queue,
            AdmissionConfig::TokenBucket { .. } => {
                if self.per_tenant {
                    // Tasks outside the registry (untenanted or foreign
                    // ids) own no bucket; admitting them — rather than
                    // draining some real tenant's tokens — mirrors how the
                    // queue and metrics route them to a fallback.
                    match tenant.and_then(|t| self.buckets.get_mut(t as usize)) {
                        Some(bucket) => bucket.take(now),
                        None => true,
                    }
                } else {
                    match self.buckets.first_mut() {
                        Some(bucket) => bucket.take(now),
                        None => true,
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admit_all_admits() {
        let mut s = AdmissionState::new(AdmissionConfig::AdmitAll, None);
        for i in 0..100 {
            assert!(s.admit(None, i as f64, i));
        }
    }

    #[test]
    fn drop_tail_bounds_queue() {
        let mut s = AdmissionState::new(AdmissionConfig::DropTail { max_queue: 4 }, None);
        assert!(s.admit(None, 0.0, 3));
        assert!(!s.admit(None, 0.0, 4));
        assert!(!s.admit(None, 0.0, 9));
    }

    #[test]
    fn token_bucket_spends_burst_then_rate_limits() {
        let mut s = AdmissionState::new(
            AdmissionConfig::TokenBucket { rate: 1.0, burst: 3.0 },
            None,
        );
        // Burst: three back-to-back admits, then empty.
        assert!(s.admit(None, 0.0, 0));
        assert!(s.admit(None, 0.0, 0));
        assert!(s.admit(None, 0.0, 0));
        assert!(!s.admit(None, 0.0, 0));
        // One second refills one token.
        assert!(s.admit(None, 1.0, 0));
        assert!(!s.admit(None, 1.0, 0));
    }

    #[test]
    fn per_tenant_buckets_ignore_untracked_tasks() {
        use crate::qos::{TenantRegistry, TenantsConfig};
        let reg = TenantRegistry::new(&TenantsConfig::three_tier(0.3));
        let mut s = AdmissionState::new(
            AdmissionConfig::TokenBucket { rate: 0.1, burst: 3.0 },
            Some(&reg),
        );
        // Untenanted and foreign-id tasks own no bucket: always admitted,
        // and they must not drain any real tenant's tokens.
        for _ in 0..50 {
            assert!(s.admit(None, 0.0, 0));
            assert!(s.admit(Some(99), 0.0, 0));
        }
        // Premium's full burst is still available afterwards.
        let mut admitted = 0;
        while s.admit(Some(0), 0.0, 0) {
            admitted += 1;
        }
        assert!(admitted >= 1, "premium bucket drained by untracked tasks");
    }

    #[test]
    fn bad_configs_rejected() {
        assert!(AdmissionConfig::DropTail { max_queue: 0 }.validate().is_err());
        assert!(AdmissionConfig::TokenBucket { rate: 0.0, burst: 4.0 }
            .validate()
            .is_err());
        assert!(AdmissionConfig::TokenBucket { rate: 1.0, burst: 0.5 }
            .validate()
            .is_err());
        assert!(AdmissionConfig::AdmitAll.validate().is_ok());
    }

    #[test]
    fn json_roundtrip() {
        for cfg in [
            AdmissionConfig::AdmitAll,
            AdmissionConfig::DropTail { max_queue: 32 },
            AdmissionConfig::TokenBucket { rate: 0.25, burst: 8.0 },
        ] {
            let back = AdmissionConfig::from_json(&cfg.to_json()).unwrap();
            assert_eq!(back, cfg);
        }
        let mut v = Value::obj();
        v.set("kind", "martian");
        assert!(AdmissionConfig::from_json(&v).is_err());
    }
}
