//! Per-task lifecycle tracing: typed span events in a bounded ring
//! buffer, exportable as JSONL.
//!
//! Every event is `Copy` (gang members live in a fixed inline array), so
//! once the ring has grown to capacity, recording allocates nothing — the
//! hot path is a bounds check and a struct store. Recording never draws
//! from an RNG stream and never feeds back into scheduling, so a traced
//! episode is bit-identical to an untraced one (pinned by property tests
//! in `sim/env.rs`). When the ring wraps, the oldest events are evicted
//! and counted; the analyzer skips tasks whose lifecycle is incomplete
//! rather than mis-attributing their latency.

use super::schema;
use crate::util::json::{self, Value};

/// Maximum gang members stored inline per event. Gangs beyond this are
/// truncated (flagged), which the presets never reach (patch counts are
/// ≤ 8); the analyzer only needs timings, not the full membership.
pub const MAX_GANG: usize = 16;

/// A gang reference small enough to keep events `Copy`: member ids plus a
/// warm/cold bit per member (did the server already hold the task's model
/// at dispatch?).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GangRef {
    len: u8,
    truncated: bool,
    ids: [u32; MAX_GANG],
    warm: u16,
}

impl GangRef {
    /// Capture a gang; `warm(i)` answers whether member `servers[i]` is
    /// warm for the task's model.
    pub fn capture(servers: &[usize], warm: impl Fn(usize) -> bool) -> GangRef {
        let mut ids = [0u32; MAX_GANG];
        let mut warm_mask = 0u16;
        let n = servers.len().min(MAX_GANG);
        for (i, &s) in servers.iter().take(n).enumerate() {
            ids[i] = s as u32;
            if warm(i) {
                warm_mask |= 1 << i;
            }
        }
        GangRef {
            len: n as u8,
            truncated: servers.len() > MAX_GANG,
            ids,
            warm: warm_mask,
        }
    }

    pub fn members(&self) -> &[u32] {
        &self.ids[..self.len as usize]
    }

    pub fn is_warm(&self, i: usize) -> bool {
        i < self.len as usize && self.warm & (1 << i) != 0
    }

    pub fn truncated(&self) -> bool {
        self.truncated
    }
}

/// Why a task left the system without completing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DropReason {
    /// Shed by admission control on arrival.
    Admission,
    /// Killed more than `max_retries` times under churn.
    RetriesExhausted,
}

impl DropReason {
    fn name(&self) -> &'static str {
        match self {
            DropReason::Admission => "admission",
            DropReason::RetriesExhausted => "retries_exhausted",
        }
    }
}

/// The typed span-event vocabulary. Times are simulated seconds in the
/// simulator and simulated-clock seconds in `eat serve`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SpanKind {
    /// Passed admission control (t = the task's arrival instant).
    Admitted,
    /// Entered the pending queue; `depth` is the queue length after.
    Queued { depth: u32 },
    /// A gang was dispatched for this task. `cold` is the model-load time
    /// charged to this attempt (0 on full reuse), `exec` its sampled
    /// execution time, `attempt` the number of earlier kills.
    Dispatched {
        gang: GangRef,
        cold: f64,
        exec: f64,
        attempt: u32,
        speculative: bool,
    },
    /// Execution began (same instant as dispatch in the simulator; the
    /// wire-level serving path may separate them).
    ExecStart,
    /// The attempt was killed (member failure, or it lost a speculative
    /// race); `attempt` counts kills of this task so far.
    Killed { attempt: u32 },
    /// The task re-entered the queue after a kill.
    Retried { attempt: u32 },
    /// A speculative backup was launched on a warm gang.
    SpecLaunched { gang: GangRef, exec: f64 },
    /// The task completed. `response` is the measured latency booked by
    /// the scheduler; `start` is the winning attempt's dispatch instant
    /// (matches that attempt's `Dispatched`/`SpecLaunched` event time).
    Completed {
        response: f64,
        start: f64,
        speculative: bool,
    },
    /// The task left without completing.
    Dropped { reason: DropReason },
    /// Worker-reported timings for the winning attempt's critical member
    /// (the gang member with the largest wall round-trip), merged
    /// host-side from the wire reply by `eat serve`. `rtt` is the
    /// host-measured wall round-trip; the rest are the worker's own
    /// spans: `recv` read+parse, `lock_wait` GPU-mutex wait, `load`
    /// weight load, `exec` execution, `reply` serialize+write.
    WorkerSpan {
        rtt: f64,
        recv: f64,
        lock_wait: f64,
        load: f64,
        exec: f64,
        reply: f64,
    },
}

impl SpanKind {
    pub fn name(&self) -> &'static str {
        match self {
            SpanKind::Admitted => "admitted",
            SpanKind::Queued { .. } => "queued",
            SpanKind::Dispatched { .. } => "dispatched",
            SpanKind::ExecStart => "exec_start",
            SpanKind::Killed { .. } => "killed",
            SpanKind::Retried { .. } => "retried",
            SpanKind::SpecLaunched { .. } => "spec_launched",
            SpanKind::Completed { .. } => "completed",
            SpanKind::Dropped { .. } => "dropped",
            SpanKind::WorkerSpan { .. } => "worker_span",
        }
    }
}

/// One recorded span event: when, which task, whose tenant, what.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SpanEvent {
    pub t: f64,
    pub task: u64,
    /// Tenant index, `u32::MAX` when the task has none.
    pub tenant: u32,
    pub kind: SpanKind,
}

pub const NO_TENANT: u32 = u32::MAX;

impl SpanEvent {
    pub fn tenant_opt(&self) -> Option<u32> {
        (self.tenant != NO_TENANT).then_some(self.tenant)
    }

    /// One JSONL line (no trailing newline). Key order is alphabetical
    /// (the JSON writer's object order), values round-trip f64s bit-exactly.
    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("t", self.t);
        v.set("task", self.task);
        v.set("ev", self.kind.name());
        if let Some(tn) = self.tenant_opt() {
            v.set("tenant", tn as u64);
        }
        match self.kind {
            SpanKind::Admitted | SpanKind::ExecStart => {}
            SpanKind::Queued { depth } => v.set("depth", depth as u64),
            SpanKind::Dispatched {
                gang,
                cold,
                exec,
                attempt,
                speculative,
            } => {
                set_gang(&mut v, &gang);
                v.set("cold", cold);
                v.set("exec", exec);
                v.set("attempt", attempt as u64);
                v.set("spec", speculative);
            }
            SpanKind::Killed { attempt } => v.set("attempt", attempt as u64),
            SpanKind::Retried { attempt } => v.set("attempt", attempt as u64),
            SpanKind::SpecLaunched { gang, exec } => {
                set_gang(&mut v, &gang);
                v.set("exec", exec);
            }
            SpanKind::Completed {
                response,
                start,
                speculative,
            } => {
                v.set("response", response);
                v.set("start", start);
                v.set("spec", speculative);
            }
            SpanKind::Dropped { reason } => v.set("reason", reason.name()),
            SpanKind::WorkerSpan { rtt, recv, lock_wait, load, exec, reply } => {
                v.set("rtt", rtt);
                v.set("recv", recv);
                v.set("lock_wait", lock_wait);
                v.set("load", load);
                v.set("exec", exec);
                v.set("reply", reply);
            }
        }
        v
    }

    /// Parse one JSONL line back into an event.
    pub fn from_json(v: &Value) -> anyhow::Result<SpanEvent> {
        let t = v.req("t")?.as_f64().ok_or_else(|| anyhow::anyhow!("bad t"))?;
        let task = v.req("task")?.as_f64().ok_or_else(|| anyhow::anyhow!("bad task"))? as u64;
        let tenant = match v.get("tenant").and_then(Value::as_f64) {
            Some(tn) => tn as u32,
            None => NO_TENANT,
        };
        let ev = v
            .req("ev")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("bad ev"))?
            .to_string();
        let f = |key: &str| -> anyhow::Result<f64> {
            v.req(key)?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("bad field '{key}'"))
        };
        let kind = match ev.as_str() {
            "admitted" => SpanKind::Admitted,
            "exec_start" => SpanKind::ExecStart,
            "queued" => SpanKind::Queued {
                depth: f("depth")? as u32,
            },
            "dispatched" => SpanKind::Dispatched {
                gang: gang_from(v)?,
                cold: f("cold")?,
                exec: f("exec")?,
                attempt: f("attempt")? as u32,
                speculative: v.get("spec").and_then(Value::as_bool).unwrap_or(false),
            },
            "killed" => SpanKind::Killed {
                attempt: f("attempt")? as u32,
            },
            "retried" => SpanKind::Retried {
                attempt: f("attempt")? as u32,
            },
            "spec_launched" => SpanKind::SpecLaunched {
                gang: gang_from(v)?,
                exec: f("exec")?,
            },
            "completed" => SpanKind::Completed {
                response: f("response")?,
                start: f("start")?,
                speculative: v.get("spec").and_then(Value::as_bool).unwrap_or(false),
            },
            "dropped" => SpanKind::Dropped {
                reason: match v.req("reason")?.as_str() {
                    Some("admission") => DropReason::Admission,
                    Some("retries_exhausted") => DropReason::RetriesExhausted,
                    other => anyhow::bail!("unknown drop reason {other:?}"),
                },
            },
            "worker_span" => SpanKind::WorkerSpan {
                rtt: f("rtt")?,
                recv: f("recv")?,
                lock_wait: f("lock_wait")?,
                load: f("load")?,
                exec: f("exec")?,
                reply: f("reply")?,
            },
            other => anyhow::bail!("unknown span event '{other}'"),
        };
        Ok(SpanEvent { t, task, tenant, kind })
    }
}

fn set_gang(v: &mut Value, gang: &GangRef) {
    let ids: Vec<u64> = gang.members().iter().map(|&m| m as u64).collect();
    let warm: Vec<bool> = (0..gang.members().len()).map(|i| gang.is_warm(i)).collect();
    v.set("gang", ids);
    v.set("warm", warm);
    if gang.truncated() {
        v.set("gang_truncated", true);
    }
}

fn gang_from(v: &Value) -> anyhow::Result<GangRef> {
    let ids = v
        .req("gang")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("bad gang"))?;
    let warm = v
        .req("warm")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("bad warm"))?;
    let servers: Vec<usize> = ids
        .iter()
        .map(|x| x.as_f64().map(|f| f as usize).ok_or_else(|| anyhow::anyhow!("bad gang id")))
        .collect::<anyhow::Result<_>>()?;
    let warm_bits: Vec<bool> = warm.iter().map(|x| x.as_bool().unwrap_or(false)).collect();
    Ok(GangRef::capture(&servers, |i| {
        warm_bits.get(i).copied().unwrap_or(false)
    }))
}

/// Bounded ring buffer of span events.
///
/// `record` is allocation-free once the buffer has grown to capacity:
/// the backing `Vec` is filled once and then overwritten in place, with
/// evictions counted so exports can say what was lost.
#[derive(Clone, Debug)]
pub struct TraceRecorder {
    cap: usize,
    buf: Vec<SpanEvent>,
    /// Next write position once the buffer is full (ring head).
    head: usize,
    evicted: u64,
}

impl TraceRecorder {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0, "trace capacity must be > 0");
        TraceRecorder {
            cap,
            buf: Vec::with_capacity(cap),
            head: 0,
            evicted: 0,
        }
    }

    /// Default capacity: enough for every preset episode (< 10 events per
    /// task) without pre-reserving megabytes.
    pub fn default_capacity() -> usize {
        1 << 16
    }

    pub fn record(&mut self, t: f64, task: u64, tenant: Option<u32>, kind: SpanKind) {
        let ev = SpanEvent {
            t,
            task,
            tenant: tenant.unwrap_or(NO_TENANT),
            kind,
        };
        if self.buf.len() < self.cap {
            self.buf.push(ev);
        } else {
            self.buf[self.head] = ev;
            self.head = (self.head + 1) % self.cap;
            self.evicted += 1;
        }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Events evicted by ring wrap-around (0 until the buffer fills).
    pub fn evicted(&self) -> u64 {
        self.evicted
    }

    /// Events in recording order (oldest surviving first).
    pub fn events(&self) -> Vec<SpanEvent> {
        let mut out = Vec::with_capacity(self.buf.len());
        out.extend_from_slice(&self.buf[self.head..]);
        out.extend_from_slice(&self.buf[..self.head]);
        out
    }

    /// JSONL export: one meta line carrying the surviving-event and
    /// evicted counts, then one event per line, oldest first. The meta
    /// line is what lets the analyzer distinguish "this lifecycle is
    /// corrupt" from "this lifecycle lost its head to ring wrap-around".
    pub fn to_jsonl(&self) -> String {
        let mut meta = Value::obj();
        meta.set("schema", schema::TRACE)
            .set("events", self.buf.len())
            .set("evicted", self.evicted);
        let mut out = meta.to_json();
        out.push('\n');
        for ev in self.events() {
            out.push_str(&ev.to_json().to_json());
            out.push('\n');
        }
        out
    }

    pub fn write_jsonl(&self, path: &str) -> anyhow::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.to_jsonl())?;
        Ok(())
    }
}

/// A parsed trace document: the surviving events plus how many the
/// recorder's ring evicted before export (0 for pre-meta-line traces).
#[derive(Clone, Debug)]
pub struct TraceDoc {
    pub events: Vec<SpanEvent>,
    pub evicted: u64,
}

/// Parse a JSONL trace (as written by [`TraceRecorder::to_jsonl`]) back
/// into events plus its meta counters. Blank lines are skipped; a
/// missing meta line (pre-PR-8 trace) parses with `evicted = 0`.
pub fn parse_jsonl_doc(text: &str) -> anyhow::Result<TraceDoc> {
    let mut events = Vec::new();
    let mut evicted = 0u64;
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let v = json::parse(line)
            .map_err(|e| anyhow::anyhow!("trace line {}: {e}", lineno + 1))?;
        if let Some(schema) = v.get("schema").and_then(Value::as_str) {
            anyhow::ensure!(
                schema == self::schema::TRACE,
                "trace line {}: unsupported trace schema '{schema}'",
                lineno + 1
            );
            evicted = v.get("evicted").and_then(Value::as_f64).map(|x| x as u64).unwrap_or(0);
            continue;
        }
        events.push(
            SpanEvent::from_json(&v)
                .map_err(|e| anyhow::anyhow!("trace line {}: {e}", lineno + 1))?,
        );
    }
    Ok(TraceDoc { events, evicted })
}

/// [`parse_jsonl_doc`] discarding the meta counters.
pub fn parse_jsonl(text: &str) -> anyhow::Result<Vec<SpanEvent>> {
    Ok(parse_jsonl_doc(text)?.events)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gang() -> GangRef {
        GangRef::capture(&[3, 1, 4], |i| i != 1)
    }

    #[test]
    fn gang_ref_captures_members_and_warmth() {
        let g = gang();
        assert_eq!(g.members(), &[3, 1, 4]);
        assert!(g.is_warm(0));
        assert!(!g.is_warm(1));
        assert!(g.is_warm(2));
        assert!(!g.is_warm(7));
        assert!(!g.truncated());
        let big: Vec<usize> = (0..20).collect();
        assert!(GangRef::capture(&big, |_| false).truncated());
    }

    #[test]
    fn ring_buffer_evicts_oldest_and_counts() {
        let mut tr = TraceRecorder::new(3);
        for i in 0..5u64 {
            tr.record(i as f64, i, None, SpanKind::Admitted);
        }
        assert_eq!(tr.len(), 3);
        assert_eq!(tr.evicted(), 2);
        let tasks: Vec<u64> = tr.events().iter().map(|e| e.task).collect();
        assert_eq!(tasks, vec![2, 3, 4]);
    }

    #[test]
    fn jsonl_round_trip_is_bit_exact() {
        let mut tr = TraceRecorder::new(64);
        tr.record(0.1 + 0.2, 7, Some(1), SpanKind::Admitted);
        tr.record(1.0 / 3.0, 7, Some(1), SpanKind::Queued { depth: 2 });
        tr.record(
            2.5,
            7,
            Some(1),
            SpanKind::Dispatched {
                gang: gang(),
                cold: 33.07218471984863,
                exec: 5.000000000000001,
                attempt: 0,
                speculative: false,
            },
        );
        tr.record(2.5, 7, Some(1), SpanKind::ExecStart);
        tr.record(4.0, 7, Some(1), SpanKind::Killed { attempt: 1 });
        tr.record(4.0, 7, Some(1), SpanKind::Retried { attempt: 1 });
        tr.record(
            6.0,
            7,
            Some(1),
            SpanKind::SpecLaunched { gang: gang(), exec: 5.25 },
        );
        tr.record(
            40.25,
            7,
            Some(1),
            SpanKind::Completed {
                response: 40.150000000000006,
                start: 6.0,
                speculative: true,
            },
        );
        tr.record(
            1.0,
            8,
            None,
            SpanKind::Dropped { reason: DropReason::Admission },
        );
        tr.record(
            40.25,
            7,
            Some(1),
            SpanKind::WorkerSpan {
                rtt: 0.12345678901234567,
                recv: 0.001,
                lock_wait: 0.0625,
                load: 0.03,
                exec: 0.025,
                reply: 0.0005,
            },
        );
        let text = tr.to_jsonl();
        let back = parse_jsonl(&text).unwrap();
        assert_eq!(back.len(), tr.len());
        for (a, b) in tr.events().iter().zip(&back) {
            assert_eq!(a.t.to_bits(), b.t.to_bits(), "time drifted: {a:?} vs {b:?}");
            assert_eq!(a, b, "event did not round-trip");
        }
    }

    #[test]
    fn unknown_event_is_rejected() {
        assert!(parse_jsonl("{\"t\":0,\"task\":1,\"ev\":\"warped\"}").is_err());
    }

    #[test]
    fn meta_line_carries_eviction_count() {
        let mut tr = TraceRecorder::new(3);
        for i in 0..5u64 {
            tr.record(i as f64, i, None, SpanKind::Admitted);
        }
        let text = tr.to_jsonl();
        let first = text.lines().next().unwrap();
        assert!(first.contains("\"schema\":\"eat-trace-v1\""), "{first}");
        assert!(first.contains("\"evicted\":2"), "{first}");
        let doc = parse_jsonl_doc(&text).unwrap();
        assert_eq!(doc.evicted, 2);
        assert_eq!(doc.events.len(), 3);
        // A meta-less (pre-meta) trace still parses, with evicted = 0.
        let legacy = parse_jsonl_doc("{\"t\":0,\"task\":1,\"ev\":\"admitted\"}").unwrap();
        assert_eq!(legacy.evicted, 0);
        assert_eq!(legacy.events.len(), 1);
        // A foreign schema is rejected rather than silently skipped.
        assert!(parse_jsonl_doc("{\"schema\":\"eat-bench-v1\"}").is_err());
    }
}
