//! Leveled stderr logger for progress/diagnostic output.
//!
//! Machine-readable output (tables, JSON, JSONL) goes to stdout via
//! `println!` and is never routed through here; everything that is *about*
//! a run rather than *of* it (progress lines, fault-injection notices,
//! training telemetry) goes through the `log_*!` macros and lands on
//! stderr, gated by a global level. The level comes from `EAT_LOG`
//! (`error|warn|info|debug`), defaults to `info`, and the `--quiet` flag
//! caps it at `warn`. No timestamps and no allocation on suppressed
//! calls: the macros test the level before formatting.

use std::sync::atomic::{AtomicU8, Ordering};

pub const ERROR: u8 = 0;
pub const WARN: u8 = 1;
pub const INFO: u8 = 2;
pub const DEBUG: u8 = 3;

static LEVEL: AtomicU8 = AtomicU8::new(INFO);

/// Parse a level name; `None` for unknown names.
pub fn parse_level(name: &str) -> Option<u8> {
    match name.trim().to_ascii_lowercase().as_str() {
        "error" => Some(ERROR),
        "warn" | "warning" => Some(WARN),
        "info" => Some(INFO),
        "debug" | "trace" => Some(DEBUG),
        _ => None,
    }
}

/// Install the global level from `EAT_LOG`, then apply the `--quiet` /
/// `--verbose` caps. Call once at process start; tests and library users
/// that never call it get the `info` default.
pub fn init(quiet: bool, verbose: bool) {
    let mut level = std::env::var("EAT_LOG")
        .ok()
        .and_then(|v| parse_level(&v))
        .unwrap_or(INFO);
    if verbose {
        level = level.max(DEBUG);
    }
    if quiet {
        level = level.min(WARN);
    }
    set_level(level);
}

pub fn set_level(level: u8) {
    LEVEL.store(level.min(DEBUG), Ordering::Relaxed);
}

pub fn level() -> u8 {
    LEVEL.load(Ordering::Relaxed)
}

/// Would a message at `level` currently be emitted?
pub fn enabled(level: u8) -> bool {
    level <= LEVEL.load(Ordering::Relaxed)
}

/// Log an error-level line to stderr (shown unless filtered by a hook).
#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::ERROR) {
            eprintln!($($arg)*);
        }
    };
}

/// Log a warn-level line to stderr (shown even under `--quiet`).
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::WARN) {
            eprintln!($($arg)*);
        }
    };
}

/// Log an info-level progress line to stderr (default visibility).
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::INFO) {
            eprintln!($($arg)*);
        }
    };
}

/// Log a debug-level line to stderr (needs `EAT_LOG=debug` or `--verbose`).
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::DEBUG) {
            eprintln!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_level_names() {
        assert_eq!(parse_level("error"), Some(ERROR));
        assert_eq!(parse_level("WARN"), Some(WARN));
        assert_eq!(parse_level(" info "), Some(INFO));
        assert_eq!(parse_level("debug"), Some(DEBUG));
        assert_eq!(parse_level("verbose"), None);
    }

    #[test]
    fn level_gates_monotonically() {
        let before = level();
        set_level(WARN);
        assert!(enabled(ERROR));
        assert!(enabled(WARN));
        assert!(!enabled(INFO));
        assert!(!enabled(DEBUG));
        set_level(DEBUG);
        assert!(enabled(DEBUG));
        set_level(before);
    }
}
