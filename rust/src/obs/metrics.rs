//! Live metric registry with a Prometheus text-exposition endpoint.
//!
//! A [`MetricRegistry`] holds named counters, gauges, and fixed-bucket
//! histograms (the same buckets as `workload::LatencyHistogram`, so the
//! endpoint and the offline reports agree on resolution), behind one
//! mutex so the serving loop, the health monitor, and the scrape server
//! can share it via `Arc`. [`MetricsServer`] answers every TCP
//! connection with an HTTP 200 carrying the version 0.0.4 text format —
//! scrapeable by real Prometheus or a plain `nc`/`curl`, and tested here
//! over a bare `TcpStream`.
//!
//! No ecosystem crates are available offline (see `util/mod.rs`), so both
//! the registry and the HTTP shim are hand-rolled minimal std.

use crate::workload::LatencyHistogram;
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

enum Metric {
    Counter(u64),
    Gauge(f64),
    Histogram(LatencyHistogram),
}

struct Entry {
    help: &'static str,
    metric: Metric,
}

/// Shared registry of counters, gauges, and histograms, keyed by
/// Prometheus metric name. Names are `&'static str` so the hot path
/// allocates nothing; `BTreeMap` keeps the exposition deterministically
/// sorted.
#[derive(Default)]
pub struct MetricRegistry {
    inner: Mutex<BTreeMap<&'static str, Entry>>,
    /// Per-tenant series, keyed (metric name, tenant label value). Held
    /// apart from the label-less map so its hot-path names stay
    /// allocation-free; `BTreeMap` ordering groups a name's tenants
    /// together so HELP/TYPE render once per family.
    tenant: Mutex<BTreeMap<(&'static str, String), Entry>>,
    /// (version, git hash) for the `eat_build_info` gauge — labelled like
    /// the tenant series but singular, so it keeps its own slot.
    build: Mutex<Option<(String, String)>>,
}

impl MetricRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Expose `eat_build_info{version=...,git=...} 1` so scrapes can tell
    /// which binary produced the series (standard build-info idiom).
    pub fn set_build_info(&self, version: &str, git: &str) {
        *self.build.lock().unwrap() = Some((version.to_string(), git.to_string()));
    }

    /// Add `n` to a (monotone) counter, creating it at 0 first.
    pub fn counter_add(&self, name: &'static str, help: &'static str, n: u64) {
        let mut m = self.inner.lock().unwrap();
        let e = m.entry(name).or_insert(Entry { help, metric: Metric::Counter(0) });
        if let Metric::Counter(v) = &mut e.metric {
            *v += n;
        }
    }

    /// Mirror an externally-accumulated monotone count (e.g. the health
    /// registry's recovery total) into a counter. Never moves backwards.
    pub fn counter_set(&self, name: &'static str, help: &'static str, v: u64) {
        let mut m = self.inner.lock().unwrap();
        let e = m.entry(name).or_insert(Entry { help, metric: Metric::Counter(0) });
        if let Metric::Counter(cur) = &mut e.metric {
            *cur = (*cur).max(v);
        }
    }

    pub fn gauge_set(&self, name: &'static str, help: &'static str, v: f64) {
        let mut m = self.inner.lock().unwrap();
        let e = m.entry(name).or_insert(Entry { help, metric: Metric::Gauge(0.0) });
        if let Metric::Gauge(cur) = &mut e.metric {
            *cur = v;
        }
    }

    /// Observe a sample into a histogram (created with the default
    /// latency buckets on first use: 0.5 s resolution out to 2048 s).
    pub fn observe(&self, name: &'static str, help: &'static str, x: f64) {
        let mut m = self.inner.lock().unwrap();
        let e = m.entry(name).or_insert_with(|| Entry {
            help,
            metric: Metric::Histogram(LatencyHistogram::default_latency()),
        });
        if let Metric::Histogram(h) = &mut e.metric {
            h.observe(x);
        }
    }

    /// Mirror a per-tenant monotone count into a `{tenant=...}` labelled
    /// counter. Never moves backwards (same discipline as `counter_set`).
    pub fn tenant_counter_set(&self, name: &'static str, help: &'static str, tenant: &str, v: u64) {
        let mut m = self.tenant.lock().unwrap();
        let e = m
            .entry((name, tenant.to_string()))
            .or_insert(Entry { help, metric: Metric::Counter(0) });
        if let Metric::Counter(cur) = &mut e.metric {
            *cur = (*cur).max(v);
        }
    }

    /// Set a `{tenant=...}` labelled gauge.
    pub fn tenant_gauge_set(&self, name: &'static str, help: &'static str, tenant: &str, v: f64) {
        let mut m = self.tenant.lock().unwrap();
        let e = m
            .entry((name, tenant.to_string()))
            .or_insert(Entry { help, metric: Metric::Gauge(0.0) });
        if let Metric::Gauge(cur) = &mut e.metric {
            *cur = v;
        }
    }

    /// Current value of a per-tenant counter (testing / internal checks).
    pub fn tenant_counter(&self, name: &str, tenant: &str) -> u64 {
        self.tenant
            .lock()
            .unwrap()
            .iter()
            .find(|((n, t), _)| *n == name && t == tenant)
            .map_or(0, |(_, e)| match e.metric {
                Metric::Counter(v) => v,
                _ => 0,
            })
    }

    /// Current value of a counter (testing / internal checks).
    pub fn counter(&self, name: &str) -> u64 {
        match self.inner.lock().unwrap().get(name).map(|e| match e.metric {
            Metric::Counter(v) => v,
            _ => 0,
        }) {
            Some(v) => v,
            None => 0,
        }
    }

    /// Render the registry in the Prometheus text exposition format
    /// (version 0.0.4). Histograms emit cumulative `_bucket{le=...}`
    /// series over the non-empty prefix of the fixed buckets, plus
    /// `_sum`/`_count`.
    pub fn render(&self) -> String {
        let m = self.inner.lock().unwrap();
        let mut out = String::new();
        if let Some((version, git)) = self.build.lock().unwrap().as_ref() {
            out.push_str("# HELP eat_build_info build metadata of the serving binary\n");
            out.push_str("# TYPE eat_build_info gauge\n");
            out.push_str(&format!(
                "eat_build_info{{version=\"{version}\",git=\"{git}\"}} 1\n"
            ));
        }
        for (name, e) in m.iter() {
            out.push_str(&format!("# HELP {name} {}\n", e.help));
            match &e.metric {
                Metric::Counter(v) => {
                    out.push_str(&format!("# TYPE {name} counter\n{name} {v}\n"));
                }
                Metric::Gauge(v) => {
                    out.push_str(&format!("# TYPE {name} gauge\n{name} {}\n", fmt_f64(*v)));
                }
                Metric::Histogram(h) => {
                    out.push_str(&format!("# TYPE {name} histogram\n"));
                    let counts = h.bucket_counts();
                    // Every bucket up to the last non-empty one: complete
                    // enough to reconstruct quantiles, without emitting
                    // 4096 zero lines per scrape.
                    let last = counts.iter().rposition(|&c| c > 0).map_or(0, |i| i + 1);
                    let mut cum = 0u64;
                    for (i, &c) in counts.iter().take(last).enumerate() {
                        cum += c;
                        let le = (i + 1) as f64 * h.bucket_width();
                        out.push_str(&format!(
                            "{name}_bucket{{le=\"{}\"}} {cum}\n",
                            fmt_f64(le)
                        ));
                    }
                    out.push_str(&format!(
                        "{name}_bucket{{le=\"+Inf\"}} {}\n",
                        h.count()
                    ));
                    out.push_str(&format!("{name}_sum {}\n", fmt_f64(h.sum())));
                    out.push_str(&format!("{name}_count {}\n", h.count()));
                }
            }
        }
        // Per-tenant families last: HELP/TYPE once per name, then one
        // `name{tenant="..."} value` line per tenant (the map's
        // (name, label) ordering keeps each family contiguous).
        let t = self.tenant.lock().unwrap();
        let mut last_name = "";
        for ((name, label), e) in t.iter() {
            if *name != last_name {
                out.push_str(&format!("# HELP {name} {}\n", e.help));
                let kind = match e.metric {
                    Metric::Counter(_) => "counter",
                    Metric::Gauge(_) => "gauge",
                    Metric::Histogram(_) => "untyped",
                };
                out.push_str(&format!("# TYPE {name} {kind}\n"));
                last_name = name;
            }
            let label = label.replace('\\', "\\\\").replace('"', "\\\"");
            match &e.metric {
                Metric::Counter(v) => {
                    out.push_str(&format!("{name}{{tenant=\"{label}\"}} {v}\n"));
                }
                Metric::Gauge(v) => {
                    out.push_str(&format!("{name}{{tenant=\"{label}\"}} {}\n", fmt_f64(*v)));
                }
                Metric::Histogram(_) => {}
            }
        }
        out
    }
}

fn fmt_f64(x: f64) -> String {
    if x.is_nan() {
        "NaN".to_string()
    } else if x == f64::INFINITY {
        "+Inf".to_string()
    } else if x == f64::NEG_INFINITY {
        "-Inf".to_string()
    } else {
        format!("{x}")
    }
}

/// Minimal scrape server: accepts TCP connections, consumes whatever
/// request bytes arrive, and answers with one HTTP/1.0 response carrying
/// the current exposition. One thread, non-blocking accept loop, stopped
/// via flag (the same lifecycle idiom as `serving::HealthMonitor`).
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (e.g. `127.0.0.1:9184`, or port 0 for ephemeral) and
    /// start serving scrapes of `registry`.
    pub fn bind(addr: &str, registry: Arc<MetricRegistry>) -> anyhow::Result<MetricsServer> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow::anyhow!("metrics endpoint {addr}: {e}"))?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_flag = stop.clone();
        let handle = std::thread::spawn(move || {
            'accept: while !stop_flag.load(Ordering::Relaxed) {
                // Drain EVERY pending connection before sleeping: under
                // concurrent scrapers (or a dashboard refreshing several
                // panels), one-accept-per-5ms-tick queues them ~5 ms
                // apart each and backs up the listener.
                loop {
                    match listener.accept() {
                        Ok((mut stream, _)) => {
                            stream.set_nonblocking(false).ok();
                            stream
                                .set_read_timeout(Some(std::time::Duration::from_millis(250)))
                                .ok();
                            // Drain the request head; scrape clients always
                            // write before reading, but nothing here depends
                            // on the bytes.
                            let mut buf = [0u8; 1024];
                            let _ = stream.read(&mut buf);
                            let body = registry.render();
                            let resp = format!(
                                "HTTP/1.0 200 OK\r\nContent-Type: text/plain; version=0.0.4; charset=utf-8\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
                                body.len()
                            );
                            let _ = stream.write_all(resp.as_bytes());
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                        Err(_) => break 'accept,
                    }
                }
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
        });
        Ok(MetricsServer {
            addr: local,
            stop,
            handle: Some(handle),
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the accept loop and join its thread.
    pub fn stop(mut self) {
        self.stop_inner();
    }

    fn stop_inner(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            h.join().ok();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop_inner();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::TcpStream;

    #[test]
    fn registry_renders_counters_gauges_histograms() {
        let reg = MetricRegistry::new();
        reg.counter_add("eat_dispatches_total", "gangs dispatched", 3);
        reg.counter_set("eat_recoveries_total", "worker recoveries", 2);
        reg.counter_set("eat_recoveries_total", "worker recoveries", 1); // never backwards
        reg.gauge_set("eat_workers_up", "workers currently up", 4.0);
        reg.observe("eat_task_latency_seconds", "task latency", 0.2);
        reg.observe("eat_task_latency_seconds", "task latency", 1.4);
        let text = reg.render();
        assert!(text.contains("# TYPE eat_dispatches_total counter"));
        assert!(text.contains("eat_dispatches_total 3"));
        assert!(text.contains("eat_recoveries_total 2"));
        assert!(text.contains("# TYPE eat_workers_up gauge"));
        assert!(text.contains("eat_workers_up 4"));
        assert!(text.contains("# TYPE eat_task_latency_seconds histogram"));
        assert!(text.contains("eat_task_latency_seconds_bucket{le=\"0.5\"} 1"));
        assert!(text.contains("eat_task_latency_seconds_bucket{le=\"1.5\"} 2"));
        assert!(text.contains("eat_task_latency_seconds_bucket{le=\"+Inf\"} 2"));
        assert!(text.contains("eat_task_latency_seconds_count 2"));
        assert_eq!(reg.counter("eat_dispatches_total"), 3);
        // Exposition discipline: every series line is HELP, TYPE, or
        // `name[{labels}] value`.
        for line in text.lines() {
            assert!(
                line.starts_with('#')
                    || line.split_whitespace().count() == 2,
                "malformed exposition line: {line:?}"
            );
        }
    }

    #[test]
    fn build_info_renders_one_labelled_gauge() {
        let reg = MetricRegistry::new();
        reg.set_build_info("0.1.0", "abc1234");
        reg.counter_add("eat_dispatches_total", "gangs dispatched", 1);
        let text = reg.render();
        assert!(text.starts_with("# HELP eat_build_info"), "{text}");
        assert!(text.contains("# TYPE eat_build_info gauge"), "{text}");
        assert!(
            text.contains("eat_build_info{version=\"0.1.0\",git=\"abc1234\"} 1"),
            "{text}"
        );
        // The labelled series still honours the two-field line discipline
        // (no whitespace inside the label block).
        for line in text.lines() {
            assert!(
                line.starts_with('#') || line.split_whitespace().count() == 2,
                "malformed exposition line: {line:?}"
            );
        }
        // Without build info the series is absent entirely.
        assert!(!MetricRegistry::new().render().contains("eat_build_info"));
    }

    #[test]
    fn tenant_series_render_grouped_and_labelled() {
        let reg = MetricRegistry::new();
        reg.tenant_counter_set("eat_tenant_deadline_hits_total", "deadline hits", "premium", 5);
        reg.tenant_counter_set("eat_tenant_deadline_hits_total", "deadline hits", "batch", 2);
        reg.tenant_counter_set("eat_tenant_deadline_misses_total", "deadline misses", "batch", 1);
        reg.tenant_gauge_set("eat_tenant_slo_attainment", "hit fraction", "premium", 1.0);
        reg.tenant_gauge_set("eat_tenant_slo_attainment", "hit fraction", "batch", 2.0 / 3.0);
        // Monotone per label: a stale mirror never rolls a tenant back.
        reg.tenant_counter_set("eat_tenant_deadline_hits_total", "deadline hits", "premium", 3);
        let text = reg.render();
        assert!(text.contains("# TYPE eat_tenant_deadline_hits_total counter"));
        assert!(text.contains("eat_tenant_deadline_hits_total{tenant=\"premium\"} 5"), "{text}");
        assert!(text.contains("eat_tenant_deadline_hits_total{tenant=\"batch\"} 2"));
        assert!(text.contains("eat_tenant_deadline_misses_total{tenant=\"batch\"} 1"));
        assert!(text.contains("eat_tenant_slo_attainment{tenant=\"premium\"} 1"));
        assert_eq!(reg.tenant_counter("eat_tenant_deadline_hits_total", "premium"), 5);
        assert_eq!(reg.tenant_counter("eat_tenant_deadline_hits_total", "absent"), 0);
        // HELP/TYPE render once per family even with several tenants.
        assert_eq!(text.matches("# TYPE eat_tenant_deadline_hits_total").count(), 1);
        // The labelled lines keep the two-field exposition discipline.
        for line in text.lines() {
            assert!(
                line.starts_with('#') || line.split_whitespace().count() == 2,
                "malformed exposition line: {line:?}"
            );
        }
    }

    #[test]
    fn burst_of_concurrent_scrapes_all_answer() {
        let reg = Arc::new(MetricRegistry::new());
        reg.counter_add("eat_dispatches_total", "gangs dispatched", 1);
        let srv = MetricsServer::bind("127.0.0.1:0", reg.clone()).unwrap();
        let addr = srv.local_addr();
        // Open the whole burst before reading any reply: the accept loop
        // must drain every pending connection per poll tick, not answer
        // one per 5 ms sleep.
        let mut streams: Vec<TcpStream> = (0..8)
            .map(|_| {
                let mut s = TcpStream::connect(addr).unwrap();
                s.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").unwrap();
                s
            })
            .collect();
        for s in &mut streams {
            s.set_read_timeout(Some(std::time::Duration::from_secs(5))).unwrap();
            let mut text = String::new();
            s.read_to_string(&mut text).unwrap();
            assert!(text.contains("eat_dispatches_total 1"), "{text:?}");
        }
        srv.stop();
    }

    #[test]
    fn scrape_over_plain_tcp_client() {
        let reg = Arc::new(MetricRegistry::new());
        reg.counter_add("eat_recoveries_total", "worker recoveries", 1);
        let srv = MetricsServer::bind("127.0.0.1:0", reg.clone()).unwrap();
        let addr = srv.local_addr();
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").unwrap();
        let mut text = String::new();
        stream.read_to_string(&mut text).unwrap();
        assert!(text.starts_with("HTTP/1.0 200 OK"), "bad response: {text:?}");
        assert!(text.contains("text/plain; version=0.0.4"));
        assert!(text.contains("eat_recoveries_total 1"));
        // The registry is live: a second scrape sees the new value.
        reg.counter_add("eat_recoveries_total", "worker recoveries", 1);
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").unwrap();
        let mut text2 = String::new();
        stream.read_to_string(&mut text2).unwrap();
        assert!(text2.contains("eat_recoveries_total 2"));
        srv.stop();
    }
}
