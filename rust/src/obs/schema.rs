//! Central registry of wire/JSONL schema names.
//!
//! Every artifact the binary writes (traces, ledgers, time series, bench
//! reports, SLO reports, lint reports) self-identifies with a `schema`
//! field so downstream tooling can version-gate its parsers. Those names
//! used to be string literals duplicated across the emitting and the
//! consuming modules — a silent-fork hazard: bump one side and the other
//! keeps writing (or accepting) the stale name. This module is the single
//! source of truth; the `schema` lint rule rejects any `eat-*-vN` literal
//! outside it, so a name cannot drift without the change being visible
//! here. Tests that pin the *serialized* wire format keep their literals
//! on purpose (they must fail if a constant is edited carelessly).
//!
//! Bumping a version is a deliberate act: add a new `-vN+1` constant,
//! migrate writers, and keep readers accepting the old name for one
//! release if the artifact is long-lived (ledgers and traces are).

/// Per-task lifecycle span stream written by `--trace` (JSONL).
pub const TRACE: &str = "eat-trace-v1";
/// Latency-decomposition report from `eat trace analyze`.
pub const TRACE_ANALYSIS: &str = "eat-trace-analysis-v1";
/// Fleet telemetry time series written by `--timeseries` (JSONL).
pub const TIMESERIES: &str = "eat-timeseries-v1";
/// Dispatch decision ledger written by `--decisions` (JSONL).
pub const DECISIONS: &str = "eat-decisions-v1";
/// Hindsight-regret report from `eat decisions analyze`.
pub const DECISIONS_ANALYSIS: &str = "eat-decisions-analysis-v1";
/// Offline-RL experience export from `--export-experience` (JSONL).
pub const EXPERIENCE: &str = "eat-experience-v1";
/// Per-tenant error-budget report from `eat slo report`.
pub const SLO_REPORT: &str = "eat-slo-report-v1";
/// Bench grid results written by `eat bench --out`.
pub const BENCH: &str = "eat-bench-v1";
/// Per-cell regression verdicts from `eat bench compare`.
pub const BENCH_COMPARE: &str = "eat-bench-compare-v1";
/// Static-analysis findings from `eat lint --json`.
pub const LINT: &str = "eat-lint-v1";

/// Every registered schema name, for exhaustive validity checks.
pub const ALL: &[&str] = &[
    TRACE,
    TRACE_ANALYSIS,
    TIMESERIES,
    DECISIONS,
    DECISIONS_ANALYSIS,
    EXPERIENCE,
    SLO_REPORT,
    BENCH,
    BENCH_COMPARE,
    LINT,
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_are_unique_and_well_formed() {
        let mut seen = std::collections::BTreeSet::new();
        for name in ALL {
            assert!(seen.insert(*name), "duplicate schema name {name}");
            let parts: Vec<&str> = name.split('-').collect();
            assert!(parts.len() >= 3, "{name}: want eat-<name>-vN");
            assert_eq!(parts[0], "eat", "{name}: must be eat-prefixed");
            let ver = parts[parts.len() - 1];
            assert!(
                ver.len() >= 2
                    && ver.starts_with('v')
                    && ver[1..].bytes().all(|b| b.is_ascii_digit()),
                "{name}: version suffix must be vN"
            );
            for seg in &parts[1..parts.len() - 1] {
                assert!(
                    !seg.is_empty()
                        && seg
                            .bytes()
                            .all(|b| b.is_ascii_lowercase() || b.is_ascii_digit()),
                    "{name}: segment {seg:?} must be lowercase alphanumeric"
                );
            }
        }
    }

    #[test]
    fn wire_names_are_pinned() {
        // Renaming a constant must break this test: the serialized names
        // are a compatibility contract with checked-in artifacts
        // (BENCH_sim.json) and external consumers.
        assert_eq!(TRACE, "eat-trace-v1");
        assert_eq!(TIMESERIES, "eat-timeseries-v1");
        assert_eq!(DECISIONS, "eat-decisions-v1");
        assert_eq!(EXPERIENCE, "eat-experience-v1");
        assert_eq!(BENCH, "eat-bench-v1");
        assert_eq!(LINT, "eat-lint-v1");
    }
}
