//! Latency-decomposition analysis over a lifecycle trace.
//!
//! `eat trace analyze <trace.jsonl>` reconstructs every task's lifecycle
//! from its span events and decomposes the measured response latency into
//! five components:
//!
//! - **queue** — admission to first dispatch,
//! - **retry** — first dispatch to the winning attempt's dispatch (kill /
//!   re-queue rounds and speculative re-launch lead time),
//! - **cold** — the winning attempt's model-load time (0 on reuse),
//! - **exec** — the winning attempt's sampled execution time,
//! - **straggler** — everything past the nominal execution: slowdown
//!   stretch and completion-detection slack.
//!
//! The books invariant: summed in canonical order (queue + retry + cold +
//! exec + straggler, left to right) the components reproduce the measured
//! response **bit-exactly**. f64 addition is not associative, so the
//! straggler component — genuinely a residual ("time not explained by the
//! other four") — is computed by [`exact_residual`], which nudges the
//! plain difference by ulps until the canonical sum lands exactly on the
//! target. A decomposition that cannot be balanced (corrupt trace,
//! mismatched response) is reported and fails `check_books`, which the
//! CLI turns into a non-zero exit — unless the trace's meta line says the
//! ring evicted events, in which case imbalances mean *partial coverage*
//! (a lifecycle lost its head to wrap-around) and are reported instead of
//! failing the gate.
//!
//! Traces from a live `eat serve` run additionally carry `worker_span`
//! events (worker-reported wall-clock timings for the winning attempt's
//! critical gang member). Those decompose the host-measured round-trip
//! into **network / worker-queue (GPU-mutex wait) / cold (weight load) /
//! exec** with the same bit-exact books discipline: network is the
//! [`exact_residual`] of the RTT against the worker's own spans, so the
//! worker's recv/reply serialization and the wire both fold into it.

use super::schema;
use super::trace::{SpanEvent, SpanKind};
use crate::util::json::Value;
use crate::util::table::{f, Table};
use crate::workload::LatencyHistogram;
use std::collections::BTreeMap;

/// `s` such that `partial + s` rounds to `target` bit-exactly.
///
/// Starts from the plain difference and walks by ulps. Whenever `partial`
/// and `target` are within a factor of two, Sterbenz's lemma makes the
/// difference exact and zero steps are needed; the walk covers the
/// heavy-straggler regime (`partial` ≪ `target`) where one ulp of
/// correction can be required. Falls back to the plain difference if no
/// exact representation exists (never observed for non-negative
/// components; guarded by the books check downstream).
pub fn exact_residual(target: f64, partial: f64) -> f64 {
    let mut s = target - partial;
    for _ in 0..8 {
        let got = partial + s;
        if got.to_bits() == target.to_bits() {
            return s;
        }
        s = step_ulp(s, got < target);
    }
    target - partial
}

/// The adjacent f64 above (`up`) or below `x`.
fn step_ulp(x: f64, up: bool) -> f64 {
    if x.is_nan() || (up && x == f64::INFINITY) || (!up && x == f64::NEG_INFINITY) {
        return x;
    }
    if x == 0.0 {
        return if up { f64::from_bits(1) } else { -f64::from_bits(1) };
    }
    let bits = x.to_bits();
    let increase_magnitude = (x > 0.0) == up;
    f64::from_bits(if increase_magnitude { bits + 1 } else { bits - 1 })
}

/// Canonical component order of the books invariant. Every consumer of
/// the decomposition (builder, checker, report) must sum in this order.
pub fn canonical_sum(queue: f64, retry: f64, cold: f64, exec: f64, straggler: f64) -> f64 {
    (((queue + retry) + cold) + exec) + straggler
}

/// Canonical order of the live worker-span books: the network residual
/// is summed last, mirroring `canonical_sum`'s straggler.
pub fn live_sum(lock_wait: f64, load: f64, exec: f64, network: f64) -> f64 {
    ((lock_wait + load) + exec) + network
}

/// One live task's round-trip decomposition from its `worker_span` event.
/// All fields are wall-clock seconds as measured on the host (`rtt`,
/// `network`) or the worker (the rest).
#[derive(Clone, Debug)]
pub struct LiveDecomp {
    pub task: u64,
    pub tenant: Option<u32>,
    /// Host-measured wall round-trip of the critical gang member.
    pub rtt: f64,
    /// Worker-side read+parse time (informational; folded into network
    /// for the books, since the host cannot separate it from the wire).
    pub recv: f64,
    /// GPU-mutex wait on the worker: the live worker-queue component.
    pub lock_wait: f64,
    /// Weight-load (cold) time on the worker.
    pub load: f64,
    pub exec: f64,
    /// Worker-side reply serialization (informational, like `recv`).
    pub reply: f64,
    /// Residual: wire + connect + recv/reply serialization + scheduling
    /// slack — everything the worker's own spans do not explain.
    pub network: f64,
}

impl LiveDecomp {
    /// Does the live canonical sum reproduce the RTT bit-exactly?
    pub fn balanced(&self) -> bool {
        live_sum(self.lock_wait, self.load, self.exec, self.network).to_bits()
            == self.rtt.to_bits()
    }
}

/// One completed task's latency decomposition.
#[derive(Clone, Debug)]
pub struct TaskDecomp {
    pub task: u64,
    pub tenant: Option<u32>,
    pub queue: f64,
    pub retry: f64,
    pub cold: f64,
    pub exec: f64,
    pub straggler: f64,
    /// Measured response latency as booked by the scheduler.
    pub response: f64,
    /// Did the winning attempt pay a model load?
    pub cold_start: bool,
    /// Dispatch-like events seen for this task (1 = clean first attempt).
    pub attempts: u32,
    /// Did a speculative backup win the task?
    pub spec_win: bool,
}

impl TaskDecomp {
    /// Does the canonical component sum reproduce the response bit-exactly?
    pub fn balanced(&self) -> bool {
        canonical_sum(self.queue, self.retry, self.cold, self.exec, self.straggler).to_bits()
            == self.response.to_bits()
    }
}

/// Result of analyzing one trace.
#[derive(Clone, Debug, Default)]
pub struct Analysis {
    pub tasks: Vec<TaskDecomp>,
    /// Tasks with a `dropped` event (admission shed or retries exhausted).
    pub dropped: usize,
    /// Tasks whose lifecycle could not be reconstructed (ring-buffer
    /// eviction, truncated trace); skipped, never mis-attributed.
    pub incomplete: usize,
    /// Task ids whose decomposition failed the books invariant.
    pub imbalanced: Vec<u64>,
    /// Tasks whose straggler residual is materially negative — a sign the
    /// trace's component data does not belong to its response values.
    pub suspect: usize,
    /// Events the recorder's ring evicted before export (from the trace
    /// meta line). Non-zero downgrades imbalances to partial coverage.
    pub evicted: u64,
    /// Live round-trip decompositions (one per `worker_span` event).
    pub live: Vec<LiveDecomp>,
    /// Task ids whose live decomposition failed the books invariant.
    pub live_imbalanced: Vec<u64>,
}

#[derive(Default)]
struct Lifecycle {
    tenant: Option<u32>,
    admitted: Option<f64>,
    /// (t, cold, exec, speculative) per dispatch-like event, in order.
    dispatches: Vec<(f64, f64, f64, bool)>,
    completed: Option<(f64, f64, bool)>, // (response, start, spec)
    dropped: bool,
    /// (rtt, recv, lock_wait, load, exec, reply) from a worker_span.
    worker: Option<(f64, f64, f64, f64, f64, f64)>,
}

/// Decompose every completed task in `events`.
pub fn analyze(events: &[SpanEvent]) -> Analysis {
    let mut lives: BTreeMap<u64, Lifecycle> = BTreeMap::new();
    for ev in events {
        let life = lives.entry(ev.task).or_default();
        if life.tenant.is_none() {
            life.tenant = ev.tenant_opt();
        }
        match ev.kind {
            SpanKind::Admitted => life.admitted = Some(ev.t),
            SpanKind::Dispatched { cold, exec, speculative, .. } => {
                life.dispatches.push((ev.t, cold, exec, speculative));
            }
            SpanKind::SpecLaunched { exec, .. } => {
                life.dispatches.push((ev.t, 0.0, exec, true));
            }
            SpanKind::Completed { response, start, speculative } => {
                life.completed = Some((response, start, speculative));
            }
            SpanKind::Dropped { .. } => life.dropped = true,
            SpanKind::WorkerSpan { rtt, recv, lock_wait, load, exec, reply } => {
                life.worker = Some((rtt, recv, lock_wait, load, exec, reply));
            }
            SpanKind::Queued { .. }
            | SpanKind::ExecStart
            | SpanKind::Killed { .. }
            | SpanKind::Retried { .. } => {}
        }
    }

    let mut out = Analysis::default();
    for (task, life) in lives {
        if let Some((rtt, recv, lock_wait, load, exec, reply)) = life.worker {
            let network = exact_residual(rtt, live_sum(lock_wait, load, exec, 0.0));
            let d = LiveDecomp {
                task,
                tenant: life.tenant,
                rtt,
                recv,
                lock_wait,
                load,
                exec,
                reply,
                network,
            };
            if !d.balanced() {
                out.live_imbalanced.push(task);
            }
            out.live.push(d);
        }
        if life.dropped {
            out.dropped += 1;
            continue;
        }
        let Some((response, start, spec)) = life.completed else {
            // Still in flight when the trace ended, or its completion was
            // evicted — either way there is nothing to decompose.
            if !life.dispatches.is_empty() || life.admitted.is_some() {
                out.incomplete += 1;
            }
            continue;
        };
        let (Some(admitted), Some(first)) = (life.admitted, life.dispatches.first().copied())
        else {
            out.incomplete += 1;
            continue;
        };
        // The winning attempt is the dispatch-like event at the completed
        // event's recorded start instant with a matching speculative flag
        // (a retry and a speculative launch can share a tick; the flag
        // disambiguates).
        let Some(winner) = life
            .dispatches
            .iter()
            .find(|&&(t, _, _, s)| t.to_bits() == start.to_bits() && s == spec)
            .copied()
        else {
            out.incomplete += 1;
            continue;
        };
        let queue = first.0 - admitted;
        let retry = winner.0 - first.0;
        let (cold, exec) = (winner.1, winner.2);
        let straggler = exact_residual(response, canonical_sum(queue, retry, cold, exec, 0.0));
        let d = TaskDecomp {
            task,
            tenant: life.tenant,
            queue,
            retry,
            cold,
            exec,
            straggler,
            response,
            cold_start: winner.1 > 0.0,
            attempts: life.dispatches.len() as u32,
            spec_win: spec,
        };
        if !d.balanced() {
            out.imbalanced.push(task);
        }
        if d.straggler < -1e-9 * d.response.abs().max(1.0) {
            out.suspect += 1;
        }
        out.tasks.push(d);
    }
    out
}

/// [`analyze`] over a JSONL trace text, carrying the meta line's evicted
/// count into the analysis so truncated coverage is reported as partial.
pub fn analyze_jsonl(text: &str) -> anyhow::Result<Analysis> {
    let doc = super::trace::parse_jsonl_doc(text)?;
    let mut a = analyze(&doc.events);
    a.evicted = doc.evicted;
    Ok(a)
}

const COMPONENTS: [&str; 5] = ["queue", "retry", "cold", "exec", "straggler"];
const LIVE_COMPONENTS: [&str; 4] = ["network", "lock_wait", "load", "exec"];

/// Nearest-rank percentile over an already-sorted slice.
fn sorted_pct(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
    sorted[idx]
}

impl Analysis {
    fn component(&self, d: &TaskDecomp, name: &str) -> f64 {
        match name {
            "queue" => d.queue,
            "retry" => d.retry,
            "cold" => d.cold,
            "exec" => d.exec,
            "straggler" => d.straggler,
            _ => unreachable!("unknown component {name}"),
        }
    }

    fn live_component(&self, d: &LiveDecomp, name: &str) -> f64 {
        match name {
            "network" => d.network,
            "lock_wait" => d.lock_wait,
            "load" => d.load,
            "exec" => d.exec,
            "rtt" => d.rtt,
            _ => unreachable!("unknown live component {name}"),
        }
    }

    /// Fraction of completed tasks whose winning attempt paid a model load.
    pub fn cold_start_rate(&self) -> f64 {
        if self.tasks.is_empty() {
            return 0.0;
        }
        self.tasks.iter().filter(|d| d.cold_start).count() as f64 / self.tasks.len() as f64
    }

    /// Non-zero exit condition for the CLI: every decomposition must
    /// balance bit-exactly — unless the ring evicted events, in which
    /// case an imbalance means a lifecycle lost data to wrap-around and
    /// coverage is reported as partial instead of failing the gate.
    pub fn check_books(&self) -> anyhow::Result<()> {
        if self.evicted > 0 {
            return Ok(());
        }
        anyhow::ensure!(
            self.imbalanced.is_empty(),
            "latency books imbalance: {} of {} tasks do not decompose to their measured \
             latency (first offenders: {:?})",
            self.imbalanced.len(),
            self.tasks.len(),
            &self.imbalanced[..self.imbalanced.len().min(5)]
        );
        anyhow::ensure!(
            self.live_imbalanced.is_empty(),
            "live worker-span books imbalance: {} of {} round-trips do not decompose to \
             their measured RTT (first offenders: {:?})",
            self.live_imbalanced.len(),
            self.live.len(),
            &self.live_imbalanced[..self.live_imbalanced.len().min(5)]
        );
        Ok(())
    }

    /// Per-component and per-tenant report, rendered with the sweeps'
    /// table style.
    pub fn render(&self, source: &str) -> String {
        let n = self.tasks.len();
        let total_response: f64 = self.tasks.iter().map(|d| d.response).sum();
        let mut out = String::new();

        let coverage = if self.evicted > 0 {
            format!(
                ", PARTIAL coverage: {} events evicted, {} imbalanced",
                self.evicted,
                self.imbalanced.len()
            )
        } else {
            String::new()
        };
        let mut comp_table = Table::new(
            &format!(
                "Latency decomposition: {source} ({n} completed, {} dropped, {} incomplete, \
                 cold-start rate {:.1}%{coverage})",
                self.dropped,
                self.incomplete,
                self.cold_start_rate() * 100.0
            ),
            &["component", "share%", "mean", "p50", "p90", "p99", "max"],
        );
        for name in COMPONENTS.iter().chain(["response"].iter()) {
            let mut hist = LatencyHistogram::default_latency();
            let mut sum = 0.0;
            let mut max = 0.0f64;
            for d in &self.tasks {
                let x = if *name == "response" { d.response } else { self.component(d, name) };
                hist.observe(x);
                sum += x;
                max = max.max(x);
            }
            let share = if total_response > 0.0 { 100.0 * sum / total_response } else { 0.0 };
            comp_table.row(vec![
                name.to_string(),
                f(share, 1),
                f(if n > 0 { sum / n as f64 } else { 0.0 }, 2),
                f(hist.p50(), 1),
                f(hist.p90(), 1),
                f(hist.p99(), 1),
                f(max, 1),
            ]);
        }
        out.push_str(&comp_table.render());

        let mut tenants: BTreeMap<Option<u32>, Vec<&TaskDecomp>> = BTreeMap::new();
        for d in &self.tasks {
            tenants.entry(d.tenant).or_default().push(d);
        }
        if tenants.keys().any(Option::is_some) {
            let mut tt = Table::new(
                "Per-tenant decomposition",
                &["tenant", "tasks", "cold%", "queue p99", "retry p99", "p50", "p90", "p99"],
            );
            for (tenant, ds) in &tenants {
                let mut resp = LatencyHistogram::default_latency();
                let mut queue = LatencyHistogram::default_latency();
                let mut retry = LatencyHistogram::default_latency();
                let cold = ds.iter().filter(|d| d.cold_start).count();
                for d in ds {
                    resp.observe(d.response);
                    queue.observe(d.queue);
                    retry.observe(d.retry);
                }
                tt.row(vec![
                    tenant.map_or("-".to_string(), |t| format!("{t}")),
                    format!("{}", ds.len()),
                    f(100.0 * cold as f64 / ds.len() as f64, 1),
                    f(queue.p99(), 1),
                    f(retry.p99(), 1),
                    f(resp.p50(), 1),
                    f(resp.p90(), 1),
                    f(resp.p99(), 1),
                ]);
            }
            out.push('\n');
            out.push_str(&tt.render());
        }

        if !self.live.is_empty() {
            let total_rtt: f64 = self.live.iter().map(|d| d.rtt).sum();
            let mut lt = Table::new(
                &format!(
                    "Live round-trip decomposition ({} worker spans, {} imbalanced)",
                    self.live.len(),
                    self.live_imbalanced.len()
                ),
                &["component", "share%", "mean ms", "p50 ms", "p99 ms", "max ms"],
            );
            for name in LIVE_COMPONENTS.iter().chain(["rtt"].iter()) {
                let mut xs: Vec<f64> =
                    self.live.iter().map(|d| self.live_component(d, name)).collect();
                xs.sort_by(f64::total_cmp);
                let sum: f64 = xs.iter().sum();
                let share = if total_rtt > 0.0 { 100.0 * sum / total_rtt } else { 0.0 };
                lt.row(vec![
                    name.to_string(),
                    f(share, 1),
                    f(sum / xs.len() as f64 * 1e3, 2),
                    f(sorted_pct(&xs, 0.50) * 1e3, 2),
                    f(sorted_pct(&xs, 0.99) * 1e3, 2),
                    f(xs.last().copied().unwrap_or(0.0) * 1e3, 2),
                ]);
            }
            out.push('\n');
            out.push_str(&lt.render());
        }
        out
    }

    /// The `n` completed tasks with the worst response time, slowest
    /// first. Ties break on task id so the listing is deterministic.
    pub fn top_tasks(&self, n: usize) -> Vec<&TaskDecomp> {
        let mut ds: Vec<&TaskDecomp> = self.tasks.iter().collect();
        ds.sort_by(|a, b| {
            b.response.total_cmp(&a.response).then_with(|| a.task.cmp(&b.task))
        });
        ds.truncate(n);
        ds
    }

    /// Worst-offender table (`eat trace analyze --top N`): the N
    /// slowest tasks with their full per-component decomposition, so a
    /// tail regression can be traced to queueing, retries, cold starts,
    /// or stragglers without re-running the sweep.
    pub fn render_top(&self, n: usize) -> String {
        let top = self.top_tasks(n);
        let title = format!(
            "Worst {} of {} completed tasks by response time",
            top.len(),
            self.tasks.len()
        );
        let mut t = Table::new(
            &title,
            &[
                "task",
                "tenant",
                "response",
                "queue",
                "retry",
                "cold",
                "exec",
                "straggler",
                "tries",
                "flags",
            ],
        );
        for d in top {
            let mut flags = String::new();
            if d.cold_start {
                flags.push('C');
            }
            if d.spec_win {
                flags.push('S');
            }
            if flags.is_empty() {
                flags.push('-');
            }
            t.row(vec![
                format!("{}", d.task),
                d.tenant.map_or("-".to_string(), |t| format!("{t}")),
                f(d.response, 1),
                f(d.queue, 1),
                f(d.retry, 1),
                f(d.cold, 1),
                f(d.exec, 1),
                f(d.straggler, 1),
                format!("{}", d.attempts),
                flags,
            ]);
        }
        t.render()
    }

    /// Machine-readable report (`eat trace analyze --json`).
    pub fn to_json(&self, source: &str) -> Value {
        let mut v = Value::obj();
        v.set("schema", schema::TRACE_ANALYSIS);
        v.set("source", source);
        v.set("completed", self.tasks.len());
        v.set("dropped", self.dropped);
        v.set("incomplete", self.incomplete);
        v.set("imbalanced", self.imbalanced.len());
        v.set("evicted", self.evicted);
        v.set("partial", self.evicted > 0);
        v.set("cold_start_rate", self.cold_start_rate());
        let mut comps = Value::obj();
        for name in COMPONENTS.iter().chain(["response"].iter()) {
            let mut hist = LatencyHistogram::default_latency();
            let mut sum = 0.0;
            for d in &self.tasks {
                let x = if *name == "response" { d.response } else { self.component(d, name) };
                hist.observe(x);
                sum += x;
            }
            let mut c = Value::obj();
            c.set("sum", sum);
            c.set("mean", if self.tasks.is_empty() { 0.0 } else { sum / self.tasks.len() as f64 });
            c.set("p50", hist.p50());
            c.set("p90", hist.p90());
            c.set("p99", hist.p99());
            comps.set(name, c);
        }
        v.set("components", comps);
        let mut tenants: BTreeMap<Option<u32>, Vec<&TaskDecomp>> = BTreeMap::new();
        for d in &self.tasks {
            tenants.entry(d.tenant).or_default().push(d);
        }
        let tenant_rows: Vec<Value> = tenants
            .iter()
            .map(|(tenant, ds)| {
                let mut resp = LatencyHistogram::default_latency();
                for d in ds {
                    resp.observe(d.response);
                }
                let mut row = Value::obj();
                match tenant {
                    Some(t) => row.set("tenant", *t as u64),
                    None => row.set("tenant", Value::Null),
                };
                row.set("tasks", ds.len());
                row.set(
                    "cold_start_rate",
                    ds.iter().filter(|d| d.cold_start).count() as f64 / ds.len() as f64,
                );
                row.set("p50", resp.p50());
                row.set("p90", resp.p90());
                row.set("p99", resp.p99());
                row
            })
            .collect();
        v.set("tenants", tenant_rows);
        if !self.live.is_empty() {
            let mut live = Value::obj();
            live.set("tasks", self.live.len());
            live.set("imbalanced", self.live_imbalanced.len());
            let mut comps = Value::obj();
            for name in LIVE_COMPONENTS.iter().chain(["rtt"].iter()) {
                let mut xs: Vec<f64> =
                    self.live.iter().map(|d| self.live_component(d, name)).collect();
                xs.sort_by(f64::total_cmp);
                let sum: f64 = xs.iter().sum();
                let mut c = Value::obj();
                c.set("sum", sum);
                c.set("mean", sum / xs.len() as f64);
                c.set("p50", sorted_pct(&xs, 0.50));
                c.set("p99", sorted_pct(&xs, 0.99));
                comps.set(name, c);
            }
            live.set("components", comps);
            v.set("live", live);
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::{GangRef, TraceRecorder};
    use crate::util::rng::Pcg64;

    /// The residual construction must balance for any component mix,
    /// including heavy stragglers where partial ≪ response.
    #[test]
    fn exact_residual_balances_for_arbitrary_magnitudes() {
        let mut rng = Pcg64::new(7, 0x0B5);
        for i in 0..20_000u64 {
            let queue = rng.next_f64() * 100.0;
            let retry = if i % 3 == 0 { rng.next_f64() * 300.0 } else { 0.0 };
            let cold = if i % 2 == 0 { 20.0 + rng.next_f64() * 30.0 } else { 0.0 };
            let exec = 0.001 + rng.next_f64() * 50.0;
            // True straggler spans 0 to 100x the nominal work.
            let stretch = rng.next_f64() * 100.0;
            let response = queue + retry + cold + exec * (1.0 + stretch);
            let s = exact_residual(response, canonical_sum(queue, retry, cold, exec, 0.0));
            assert_eq!(
                canonical_sum(queue, retry, cold, exec, s).to_bits(),
                response.to_bits(),
                "imbalance at i={i}: q={queue} rt={retry} c={cold} e={exec} r={response}"
            );
        }
    }

    fn record_clean_task(tr: &mut TraceRecorder, task: u64, tenant: Option<u32>) {
        let gang = GangRef::capture(&[0, 1], |_| false);
        let (a, d) = (task as f64, task as f64 + 3.5);
        let (cold, exec) = (30.25, 5.125);
        tr.record(a, task, tenant, SpanKind::Admitted);
        tr.record(a, task, tenant, SpanKind::Queued { depth: 1 });
        tr.record(
            d,
            task,
            tenant,
            SpanKind::Dispatched { gang, cold, exec, attempt: 0, speculative: false },
        );
        tr.record(d, task, tenant, SpanKind::ExecStart);
        let response = (d - a) + (exec + cold);
        tr.record(
            d + cold + exec,
            task,
            tenant,
            SpanKind::Completed { response, start: d, speculative: false },
        );
    }

    #[test]
    fn clean_lifecycle_decomposes_with_zero_retry_and_straggler() {
        let mut tr = TraceRecorder::new(256);
        record_clean_task(&mut tr, 1, Some(0));
        record_clean_task(&mut tr, 2, None);
        let a = analyze(&tr.events());
        assert_eq!(a.tasks.len(), 2);
        a.check_books().unwrap();
        for d in &a.tasks {
            assert_eq!(d.queue, 3.5);
            assert_eq!(d.retry, 0.0);
            assert_eq!(d.cold, 30.25);
            assert_eq!(d.exec, 5.125);
            assert!(d.straggler.abs() < 1e-9, "straggler {}", d.straggler);
            assert!(d.cold_start);
            assert!(d.balanced());
        }
        assert_eq!(a.cold_start_rate(), 1.0);
        let rendered = a.render("test");
        for needle in ["queue", "retry", "cold", "exec", "straggler", "response", "p99"] {
            assert!(rendered.contains(needle), "missing {needle} in:\n{rendered}");
        }
    }

    #[test]
    fn retried_lifecycle_attributes_the_retry_component() {
        let mut tr = TraceRecorder::new(256);
        let gang = GangRef::capture(&[0], |_| true);
        tr.record(0.0, 9, None, SpanKind::Admitted);
        tr.record(
            2.0,
            9,
            None,
            SpanKind::Dispatched { gang, cold: 0.0, exec: 10.0, attempt: 0, speculative: false },
        );
        tr.record(5.0, 9, None, SpanKind::Killed { attempt: 1 });
        tr.record(5.0, 9, None, SpanKind::Retried { attempt: 1 });
        tr.record(
            8.0,
            9,
            None,
            SpanKind::Dispatched { gang, cold: 25.0, exec: 10.5, attempt: 1, speculative: false },
        );
        // Completed 4 s past nominal: straggler slack.
        tr.record(
            47.5,
            9,
            None,
            SpanKind::Completed { response: 47.5, start: 8.0, speculative: false },
        );
        let a = analyze(&tr.events());
        assert_eq!(a.tasks.len(), 1);
        a.check_books().unwrap();
        let d = &a.tasks[0];
        assert_eq!(d.queue, 2.0);
        assert_eq!(d.retry, 6.0);
        assert_eq!(d.cold, 25.0);
        assert_eq!(d.exec, 10.5);
        assert!((d.straggler - 4.0).abs() < 1e-9);
        assert_eq!(d.attempts, 2);
        assert_eq!(a.suspect, 0);
    }

    #[test]
    fn top_tasks_rank_by_response_with_deterministic_ties() {
        let mut tr = TraceRecorder::new(256);
        // Three clean tasks share one response; the retried task is slower.
        record_clean_task(&mut tr, 3, Some(1));
        record_clean_task(&mut tr, 1, Some(0));
        record_clean_task(&mut tr, 2, None);
        let gang = GangRef::capture(&[0], |_| true);
        tr.record(0.0, 9, None, SpanKind::Admitted);
        tr.record(
            2.0,
            9,
            None,
            SpanKind::Dispatched { gang, cold: 25.0, exec: 10.0, attempt: 0, speculative: false },
        );
        tr.record(
            47.5,
            9,
            None,
            SpanKind::Completed { response: 47.5, start: 2.0, speculative: false },
        );
        let a = analyze(&tr.events());
        assert_eq!(a.tasks.len(), 4);
        let top = a.top_tasks(3);
        assert_eq!(top.iter().map(|d| d.task).collect::<Vec<_>>(), vec![9, 1, 2]);
        assert!(a.top_tasks(100).len() == 4, "n beyond len clamps to len");
        let rendered = a.render_top(2);
        assert!(rendered.contains("Worst 2 of 4"), "header missing in:\n{rendered}");
        for needle in ["task", "tenant", "response", "queue", "straggler", "tries", "flags"] {
            assert!(rendered.contains(needle), "missing column {needle} in:\n{rendered}");
        }
        assert!(rendered.contains('9') && rendered.contains("47.5"), "worst task row:\n{rendered}");
    }

    #[test]
    fn corrupt_response_fails_the_books_check() {
        let mut tr = TraceRecorder::new(64);
        record_clean_task(&mut tr, 1, None);
        let mut events = tr.events();
        for ev in &mut events {
            if let SpanKind::Completed { response, .. } = &mut ev.kind {
                // A response smaller than cold + exec cannot balance with
                // non-negative-capped... it still balances via a negative
                // residual, so corrupt the *start* link instead? No: a
                // negative residual still sums exactly. Corrupt response
                // to NaN, which can never balance.
                *response = f64::NAN;
            }
        }
        let a = analyze(&events);
        assert!(a.check_books().is_err());
    }

    #[test]
    fn worker_spans_decompose_live_round_trips_exactly() {
        let mut tr = TraceRecorder::new(64);
        record_clean_task(&mut tr, 1, Some(0));
        // Worker spans that do NOT sum to the RTT (recv/reply/wire live
        // in the residual): network must absorb the gap bit-exactly.
        tr.record(
            10.0,
            1,
            Some(0),
            SpanKind::WorkerSpan {
                rtt: 0.1 + 0.2, // deliberately awkward f64
                recv: 0.0003,
                lock_wait: 0.05,
                load: 0.125,
                exec: 0.1,
                reply: 0.0001,
            },
        );
        let a = analyze(&tr.events());
        assert_eq!(a.live.len(), 1);
        a.check_books().unwrap();
        let d = &a.live[0];
        assert!(d.balanced(), "live books do not balance: {d:?}");
        assert_eq!(
            live_sum(d.lock_wait, d.load, d.exec, d.network).to_bits(),
            d.rtt.to_bits()
        );
        let rendered = a.render("test");
        assert!(rendered.contains("Live round-trip"), "{rendered}");
        assert!(rendered.contains("network"), "{rendered}");
        let doc = a.to_json("test").to_json();
        assert!(doc.contains("\"live\""), "{doc}");
    }

    #[test]
    fn evicted_trace_reports_partial_coverage_instead_of_failing() {
        let mut tr = TraceRecorder::new(64);
        record_clean_task(&mut tr, 1, None);
        let mut events = tr.events();
        for ev in &mut events {
            if let SpanKind::Completed { response, .. } = &mut ev.kind {
                *response = f64::NAN; // never balances
            }
        }
        let mut a = analyze(&events);
        assert_eq!(a.imbalanced, vec![1]);
        assert!(a.check_books().is_err(), "full coverage must still gate");
        a.evicted = 17;
        a.check_books().unwrap();
        let rendered = a.render("test");
        assert!(rendered.contains("PARTIAL"), "{rendered}");
        assert!(rendered.contains("17"), "{rendered}");
    }

    #[test]
    fn analyze_jsonl_picks_up_the_meta_eviction_count() {
        let mut tr = TraceRecorder::new(2);
        record_clean_task(&mut tr, 1, None); // 5 events into a 2-ring
        let a = analyze_jsonl(&tr.to_jsonl()).unwrap();
        assert_eq!(a.evicted, 3);
        a.check_books().unwrap();
    }

    #[test]
    fn incomplete_lifecycles_are_skipped_not_misattributed() {
        let mut tr = TraceRecorder::new(64);
        // Completed event with no admitted/dispatched history (evicted).
        tr.record(
            10.0,
            3,
            None,
            SpanKind::Completed { response: 8.0, start: 5.0, speculative: false },
        );
        let a = analyze(&tr.events());
        assert_eq!(a.tasks.len(), 0);
        assert_eq!(a.incomplete, 1);
        a.check_books().unwrap();
    }
}
