//! Fleet telemetry time-series: a deterministic fixed-cadence sampler.
//!
//! [`FleetSampler`] closes one window every `cadence` simulated seconds
//! and records how the fleet looked at that boundary (queue depth,
//! busy/up servers, in-flight gangs) plus what happened *during* the
//! window (cold-start dispatches, per-member model weight loads,
//! per-tenant deadline hits/misses, wasted patch-seconds). Windowed
//! counters are diffs of the simulator's cumulative counters, so the
//! sampler observes without adding any accounting of its own to the hot
//! paths — and, like tracing, it never touches an RNG stream, so
//! sampling on/off leaves episodes bit-identical (pinned by property
//! test in `sim/env.rs`).
//!
//! [`FleetSeries`] is the bounded product: a ring of windows (oldest
//! evicted past capacity, eviction counted), exported as
//! `eat-timeseries-v1` JSONL — a meta line followed by one JSON object
//! per window. Series pool across episodes and across sweep shards with
//! [`FleetSeries::merge`]: windows align by absolute index and every
//! field adds, in caller order, so an N-shard `--threads` sweep merged
//! in slot order reproduces the single-shard series bit-for-bit.

use super::schema;
use crate::util::json::{self, Value};
use std::collections::VecDeque;

/// One closed sampling window.
///
/// Gauges (`queue_depth`, `busy`, `up`, `inflight`) are point samples at
/// the window boundary; the remaining fields are totals over the window.
/// `hits[i]`/`misses[i]` index tenants in registry order: a hit is a
/// completion inside its deadline, a miss is a late completion or a
/// drop (admission or retries exhausted).
#[derive(Clone, Debug, PartialEq)]
pub struct FleetSample {
    /// Absolute window index; the window covers
    /// `[window * cadence, (window + 1) * cadence)` simulated seconds.
    pub window: u64,
    pub queue_depth: u64,
    pub busy: u64,
    pub up: u64,
    pub inflight: u64,
    /// Dispatches this window that required at least one weight load.
    pub cold_starts: u64,
    /// Individual gang members that loaded weights this window.
    pub model_loads: u64,
    /// Wasted nominal patch-seconds booked this window.
    pub wasted_ps: f64,
    pub hits: Vec<u64>,
    pub misses: Vec<u64>,
}

impl FleetSample {
    fn zero(window: u64, tenants: usize) -> FleetSample {
        FleetSample {
            window,
            queue_depth: 0,
            busy: 0,
            up: 0,
            inflight: 0,
            cold_starts: 0,
            model_loads: 0,
            wasted_ps: 0.0,
            hits: vec![0; tenants],
            misses: vec![0; tenants],
        }
    }

    /// Element-wise accumulate (same window of another shard/episode).
    fn add(&mut self, other: &FleetSample) {
        debug_assert_eq!(self.window, other.window);
        self.queue_depth += other.queue_depth;
        self.busy += other.busy;
        self.up += other.up;
        self.inflight += other.inflight;
        self.cold_starts += other.cold_starts;
        self.model_loads += other.model_loads;
        self.wasted_ps += other.wasted_ps;
        for (a, b) in self.hits.iter_mut().zip(&other.hits) {
            *a += b;
        }
        for (a, b) in self.misses.iter_mut().zip(&other.misses) {
            *a += b;
        }
    }

    fn to_json(&self, cadence: f64) -> Value {
        let mut v = Value::obj();
        v.set("window", self.window)
            .set("t", (self.window + 1) as f64 * cadence)
            .set("queue", self.queue_depth)
            .set("busy", self.busy)
            .set("up", self.up)
            .set("inflight", self.inflight)
            .set("cold_starts", self.cold_starts)
            .set("model_loads", self.model_loads)
            .set("wasted_ps", self.wasted_ps)
            .set("hits", self.hits.clone())
            .set("misses", self.misses.clone());
        v
    }

    fn from_json(v: &Value) -> anyhow::Result<FleetSample> {
        let n = |key: &str| -> anyhow::Result<f64> {
            v.req(key)?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("'{key}' is not a number"))
        };
        let counts = |key: &str| -> anyhow::Result<Vec<u64>> {
            v.req(key)?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("'{key}' is not an array"))?
                .iter()
                .map(|x| {
                    x.as_f64()
                        .map(|f| f as u64)
                        .ok_or_else(|| anyhow::anyhow!("'{key}' entry is not a number"))
                })
                .collect()
        };
        Ok(FleetSample {
            window: n("window")? as u64,
            queue_depth: n("queue")? as u64,
            busy: n("busy")? as u64,
            up: n("up")? as u64,
            inflight: n("inflight")? as u64,
            cold_starts: n("cold_starts")? as u64,
            model_loads: n("model_loads")? as u64,
            wasted_ps: n("wasted_ps")?,
            hits: counts("hits")?,
            misses: counts("misses")?,
        })
    }
}

/// Bounded window ring with tenant labels and an eviction count.
#[derive(Clone, Debug, PartialEq)]
pub struct FleetSeries {
    cadence: f64,
    cap: usize,
    samples: VecDeque<FleetSample>,
    evicted: u64,
    tenants: Vec<String>,
}

impl FleetSeries {
    pub fn new(cadence: f64, cap: usize, tenants: Vec<String>) -> FleetSeries {
        assert!(cadence > 0.0 && cadence.is_finite(), "cadence must be > 0");
        assert!(cap > 0, "series capacity must be > 0");
        FleetSeries {
            cadence,
            cap,
            samples: VecDeque::new(),
            evicted: 0,
            tenants,
        }
    }

    /// Default ring capacity: 2^14 windows.
    pub fn default_capacity() -> usize {
        1 << 14
    }

    pub fn cadence(&self) -> f64 {
        self.cadence
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn evicted(&self) -> u64 {
        self.evicted
    }

    pub fn tenants(&self) -> &[String] {
        &self.tenants
    }

    /// Windows, oldest first.
    pub fn samples(&self) -> impl Iterator<Item = &FleetSample> {
        self.samples.iter()
    }

    fn push(&mut self, s: FleetSample) {
        if self.samples.len() == self.cap {
            self.samples.pop_front();
            self.evicted += 1;
        }
        self.samples.push_back(s);
    }

    /// Pool another series into this one: windows align by absolute
    /// index and every field adds. Panics on cadence or tenant-shape
    /// mismatch (series from different configs are not poolable).
    pub fn merge(&mut self, other: &FleetSeries) {
        assert_eq!(
            self.cadence.to_bits(),
            other.cadence.to_bits(),
            "cadence mismatch"
        );
        assert_eq!(self.tenants, other.tenants, "tenant shape mismatch");
        for s in &other.samples {
            match self.samples.iter_mut().find(|m| m.window == s.window) {
                Some(m) => m.add(s),
                None => {
                    // New window: insert keeping ascending order.
                    let at = self
                        .samples
                        .iter()
                        .position(|m| m.window > s.window)
                        .unwrap_or(self.samples.len());
                    self.samples.insert(at, s.clone());
                }
            }
        }
        self.evicted += other.evicted;
        while self.samples.len() > self.cap {
            self.samples.pop_front();
            self.evicted += 1;
        }
    }

    /// Serialize as `eat-timeseries-v1` JSONL: one meta line, then one
    /// JSON object per window, oldest first.
    pub fn to_jsonl(&self) -> String {
        let mut meta = Value::obj();
        meta.set("schema", schema::TIMESERIES)
            .set("cadence", self.cadence)
            .set("windows", self.samples.len())
            .set("evicted", self.evicted)
            .set("tenants", self.tenants.clone());
        let mut out = meta.to_json();
        out.push('\n');
        for s in &self.samples {
            out.push_str(&s.to_json(self.cadence).to_json());
            out.push('\n');
        }
        out
    }

    pub fn write_jsonl(&self, path: &str) -> anyhow::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.to_jsonl()).map_err(|e| anyhow::anyhow!("{path}: {e}"))
    }

    /// Parse an `eat-timeseries-v1` JSONL document.
    pub fn parse_jsonl(text: &str) -> anyhow::Result<FleetSeries> {
        let mut lines = text.lines().enumerate().filter(|(_, l)| !l.trim().is_empty());
        let (_, meta_line) = lines
            .next()
            .ok_or_else(|| anyhow::anyhow!("empty time-series document"))?;
        let meta = json::parse(meta_line).map_err(|e| anyhow::anyhow!("meta line: {e}"))?;
        let schema = meta.req("schema")?.as_str().unwrap_or("");
        anyhow::ensure!(
            schema == self::schema::TIMESERIES,
            "unsupported time-series schema '{schema}'"
        );
        let cadence = meta
            .req("cadence")?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("meta 'cadence' is not a number"))?;
        let tenants: Vec<String> = meta
            .req("tenants")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("meta 'tenants' is not an array"))?
            .iter()
            .map(|t| {
                t.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| anyhow::anyhow!("tenant name is not a string"))
            })
            .collect::<anyhow::Result<_>>()?;
        let mut series = FleetSeries::new(cadence, Self::default_capacity(), tenants);
        series.evicted = meta
            .get("evicted")
            .and_then(Value::as_f64)
            .map(|x| x as u64)
            .unwrap_or(0);
        for (i, line) in lines {
            let v = json::parse(line).map_err(|e| anyhow::anyhow!("line {}: {e}", i + 1))?;
            series.push(
                FleetSample::from_json(&v).map_err(|e| anyhow::anyhow!("line {}: {e}", i + 1))?,
            );
        }
        Ok(series)
    }
}

/// Point-in-time fleet gauges handed to the sampler at each step
/// boundary by the environment.
#[derive(Clone, Copy, Debug, Default)]
pub struct FleetGauges {
    pub queue_depth: u64,
    pub busy: u64,
    pub up: u64,
    pub inflight: u64,
}

/// Per-tenant cumulative counters the sampler diffs into windowed
/// hits/misses (indices follow registry order).
#[derive(Clone, Debug, Default)]
pub struct TenantCum {
    pub slo_met: Vec<u64>,
    pub completed: Vec<u64>,
    pub dropped: Vec<u64>,
}

/// The sampler the environment drives: call
/// [`record_model_loads`](FleetSampler::record_model_loads) /
/// [`record_cold_start`](FleetSampler::record_cold_start) from dispatch,
/// and [`advance`](FleetSampler::advance) with the current simulated
/// clock and cumulative counters at the end of each step. Every window
/// boundary the clock has crossed closes into the series: gauges as
/// point samples, cumulative counters diffed against the previous close.
#[derive(Clone, Debug)]
pub struct FleetSampler {
    cadence: f64,
    next_window: u64,
    loads_cum: u64,
    cold_cum: u64,
    last_loads: u64,
    last_cold: u64,
    last_wasted: f64,
    last_hits: Vec<u64>,
    last_completed: Vec<u64>,
    last_dropped: Vec<u64>,
    series: FleetSeries,
}

impl FleetSampler {
    pub fn new(cadence: f64, cap: usize, tenants: Vec<String>) -> FleetSampler {
        let n = tenants.len();
        FleetSampler {
            cadence,
            next_window: 0,
            loads_cum: 0,
            cold_cum: 0,
            last_loads: 0,
            last_cold: 0,
            last_wasted: 0.0,
            last_hits: vec![0; n],
            last_completed: vec![0; n],
            last_dropped: vec![0; n],
            series: FleetSeries::new(cadence, cap, tenants),
        }
    }

    /// One gang member loaded model weights (counted at dispatch).
    pub fn record_model_loads(&mut self, n: u64) {
        self.loads_cum += n;
    }

    /// One dispatch required at least one weight load.
    pub fn record_cold_start(&mut self) {
        self.cold_cum += 1;
    }

    /// Would [`advance`](Self::advance) close at least one window at
    /// `now`? Lets callers skip gauge computation between boundaries.
    pub fn window_pending(&self, now: f64) -> bool {
        now >= (self.next_window + 1) as f64 * self.cadence
    }

    /// Close every window boundary `now` has crossed. `wasted_ps` is the
    /// cumulative wasted patch-seconds; `tenants` the cumulative
    /// per-tenant counters. Counter diffs land in the first window
    /// closed this call; later windows (a long step can cross several)
    /// carry zero deltas with repeated gauges.
    pub fn advance(&mut self, now: f64, gauges: FleetGauges, wasted_ps: f64, tenants: &TenantCum) {
        while now >= (self.next_window + 1) as f64 * self.cadence {
            self.close_window(gauges, wasted_ps, tenants);
        }
    }

    /// Close one trailing partial window unconditionally, so counter
    /// activity after the last boundary is not dropped when the series
    /// is detached. Call once, after a final [`advance`](Self::advance).
    pub fn flush(&mut self, gauges: FleetGauges, wasted_ps: f64, tenants: &TenantCum) {
        self.close_window(gauges, wasted_ps, tenants);
    }

    fn close_window(&mut self, gauges: FleetGauges, wasted_ps: f64, tenants: &TenantCum) {
        let mut s = FleetSample::zero(self.next_window, self.last_hits.len());
        s.queue_depth = gauges.queue_depth;
        s.busy = gauges.busy;
        s.up = gauges.up;
        s.inflight = gauges.inflight;
        s.cold_starts = self.cold_cum - self.last_cold;
        s.model_loads = self.loads_cum - self.last_loads;
        s.wasted_ps = wasted_ps - self.last_wasted;
        for i in 0..self.last_hits.len() {
            let met = tenants.slo_met.get(i).copied().unwrap_or(0);
            let done = tenants.completed.get(i).copied().unwrap_or(0);
            let dropped = tenants.dropped.get(i).copied().unwrap_or(0);
            s.hits[i] = met - self.last_hits[i];
            s.misses[i] = (done - met + dropped) - (self.last_completed[i] - self.last_hits[i])
                - self.last_dropped[i];
            self.last_hits[i] = met;
            self.last_completed[i] = done;
            self.last_dropped[i] = dropped;
        }
        self.last_cold = self.cold_cum;
        self.last_loads = self.loads_cum;
        self.last_wasted = wasted_ps;
        self.series.push(s);
        self.next_window += 1;
    }

    pub fn into_series(self) -> FleetSeries {
        self.series
    }

    pub fn series(&self) -> &FleetSeries {
        &self.series
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sampler() -> FleetSampler {
        FleetSampler::new(10.0, 8, vec!["premium".into(), "batch".into()])
    }

    #[test]
    fn windows_close_on_boundaries_and_diff_counters() {
        let mut s = sampler();
        let mut cum = TenantCum {
            slo_met: vec![0, 0],
            completed: vec![0, 0],
            dropped: vec![0, 0],
        };
        s.record_model_loads(3);
        s.record_cold_start();
        // Mid-window: nothing closes.
        s.advance(9.5, FleetGauges::default(), 0.0, &cum);
        assert!(s.series().is_empty());
        cum.slo_met = vec![2, 0];
        cum.completed = vec![2, 1];
        cum.dropped = vec![0, 1];
        let g = FleetGauges { queue_depth: 4, busy: 3, up: 7, inflight: 2 };
        s.advance(10.0, g, 5.0, &cum);
        assert_eq!(s.series().len(), 1);
        let w0 = s.series().samples().next().unwrap().clone();
        assert_eq!(w0.window, 0);
        assert_eq!(w0.queue_depth, 4);
        assert_eq!(w0.model_loads, 3);
        assert_eq!(w0.cold_starts, 1);
        assert_eq!(w0.wasted_ps, 5.0);
        assert_eq!(w0.hits, vec![2, 0]);
        // batch: 1 late completion + 1 drop = 2 misses.
        assert_eq!(w0.misses, vec![0, 2]);
        // Second window: only the *new* activity shows up.
        s.record_model_loads(1);
        cum.slo_met = vec![3, 0];
        cum.completed = vec![3, 1];
        s.advance(20.0, g, 5.0, &cum);
        let w1 = s.series().samples().nth(1).unwrap();
        assert_eq!(w1.model_loads, 1);
        assert_eq!(w1.cold_starts, 0);
        assert_eq!(w1.wasted_ps, 0.0);
        assert_eq!(w1.hits, vec![1, 0]);
        assert_eq!(w1.misses, vec![0, 0]);
    }

    #[test]
    fn long_step_closes_every_crossed_window_once() {
        let mut s = sampler();
        let cum = TenantCum {
            slo_met: vec![0, 0],
            completed: vec![0, 0],
            dropped: vec![0, 0],
        };
        s.record_cold_start();
        s.advance(35.0, FleetGauges::default(), 2.0, &cum);
        // Crossed t=10, 20, 30: three windows; deltas in the first only.
        assert_eq!(s.series().len(), 3);
        let windows: Vec<u64> = s.series().samples().map(|w| w.window).collect();
        assert_eq!(windows, vec![0, 1, 2]);
        let cold: Vec<u64> = s.series().samples().map(|w| w.cold_starts).collect();
        assert_eq!(cold, vec![1, 0, 0]);
        let wasted: Vec<f64> = s.series().samples().map(|w| w.wasted_ps).collect();
        assert_eq!(wasted, vec![2.0, 0.0, 0.0]);
    }

    #[test]
    fn ring_evicts_oldest_and_counts() {
        let mut s = FleetSampler::new(1.0, 4, vec![]);
        let cum = TenantCum::default();
        s.advance(10.0, FleetGauges::default(), 0.0, &cum);
        let series = s.into_series();
        assert_eq!(series.len(), 4);
        assert_eq!(series.evicted(), 6);
        let first = series.samples().next().unwrap().window;
        assert_eq!(first, 6, "oldest retained window after eviction");
    }

    #[test]
    fn flush_captures_the_partial_tail_window() {
        let mut s = sampler();
        let mut cum = TenantCum {
            slo_met: vec![1, 0],
            completed: vec![1, 0],
            dropped: vec![0, 0],
        };
        s.advance(10.0, FleetGauges::default(), 0.0, &cum);
        assert_eq!(s.series().len(), 1);
        // Activity lands mid-window; the clock never reaches 20.0.
        s.record_model_loads(2);
        cum.slo_met = vec![1, 1];
        cum.completed = vec![1, 1];
        s.advance(14.0, FleetGauges::default(), 1.5, &cum);
        assert_eq!(s.series().len(), 1, "no boundary crossed yet");
        s.flush(FleetGauges { queue_depth: 1, ..FleetGauges::default() }, 1.5, &cum);
        let series = s.into_series();
        assert_eq!(series.len(), 2);
        let tail = series.samples().nth(1).unwrap();
        assert_eq!(tail.window, 1);
        assert_eq!(tail.model_loads, 2);
        assert_eq!(tail.hits, vec![0, 1]);
        assert_eq!(tail.wasted_ps, 1.5);
        assert_eq!(tail.queue_depth, 1);
    }

    #[test]
    fn jsonl_round_trip_is_exact() {
        let mut s = sampler();
        let cum = TenantCum {
            slo_met: vec![5, 1],
            completed: vec![6, 3],
            dropped: vec![0, 2],
        };
        s.record_model_loads(7);
        s.record_cold_start();
        s.record_cold_start();
        let g = FleetGauges { queue_depth: 9, busy: 5, up: 8, inflight: 3 };
        s.advance(30.0, g, 12.625, &cum);
        let series = s.into_series();
        let text = series.to_jsonl();
        let first = text.lines().next().unwrap();
        assert!(first.contains("\"schema\":\"eat-timeseries-v1\""), "{first}");
        assert!(first.contains("\"evicted\":0"), "{first}");
        let back = FleetSeries::parse_jsonl(&text).unwrap();
        assert_eq!(back, series);
        // f64 fields survive bit-exactly (shortest-round-trip writer).
        let (a, b): (Vec<u64>, Vec<u64>) = (
            series.samples().map(|w| w.wasted_ps.to_bits()).collect(),
            back.samples().map(|w| w.wasted_ps.to_bits()).collect(),
        );
        assert_eq!(a, b);
    }

    #[test]
    fn merge_adds_by_window_index() {
        let mk = |windows: &[(u64, u64)]| {
            let mut s = FleetSeries::new(10.0, 16, vec!["a".into()]);
            for &(w, hits) in windows {
                let mut sample = FleetSample::zero(w, 1);
                sample.queue_depth = w + 1;
                sample.hits[0] = hits;
                sample.wasted_ps = hits as f64 * 0.5;
                s.push(sample);
            }
            s
        };
        let mut a = mk(&[(0, 1), (1, 2)]);
        let b = mk(&[(1, 10), (2, 5)]);
        a.merge(&b);
        assert_eq!(a.len(), 3);
        let hits: Vec<u64> = a.samples().map(|w| w.hits[0]).collect();
        assert_eq!(hits, vec![1, 12, 5]);
        let queue: Vec<u64> = a.samples().map(|w| w.queue_depth).collect();
        assert_eq!(queue, vec![1, 4, 3]);
    }

    #[test]
    #[should_panic]
    fn merge_rejects_tenant_shape_mismatch() {
        let mut a = FleetSeries::new(10.0, 4, vec!["a".into()]);
        let b = FleetSeries::new(10.0, 4, vec!["b".into()]);
        a.merge(&b);
    }
}
