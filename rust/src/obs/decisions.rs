//! Per-decision scheduler explainability: a determinism-safe decision
//! ledger, hindsight-regret analysis, and an offline experience export
//! for the RL tier.
//!
//! Every dispatch decision records what the scheduler *saw* (the same
//! state vector the RL tier consumes), which candidate gangs were
//! feasible (with deterministic predicted completion times and
//! cold-start bits from `ExecModel::predict_*`), which one it chose, and
//! — joined later by task id — what actually happened. Recording never
//! draws from an RNG stream and never feeds back into scheduling, so a
//! recorded episode is bit-identical to an unrecorded one (pinned by
//! property tests in `sim/env.rs`, the same discipline as tracing and
//! sampling).
//!
//! On top of the ledger, [`DecisionAnalysis`] computes a hindsight
//! oracle per decision — the best completion any *feasible* candidate
//! could have predicted, floored at the realized outcome so regret is
//! non-negative by construction — plus per-policy/per-tenant regret
//! distributions, deadline flips (decisions where the oracle would have
//! met a deadline the policy missed), and a predicted-vs-realized
//! calibration table. [`export_experience`] turns a recorded sweep into
//! `(state, action, reward, next_state, done)` tuples loadable by
//! `rl::replay::ReplayBuffer` — offline training data for the paper's
//! attention+diffusion policy.

use super::schema;
use crate::util::json::{self, Value};
use std::collections::VecDeque;

/// One feasible dispatch alternative at decision time. `predicted` is
/// the deterministic completion estimate (`predict_exec` plus, for cold
/// placements, the predicted model reload); it never consumes RNG.
#[derive(Clone, Debug, PartialEq)]
pub struct Candidate {
    /// Gang member server ids. Empty for a hypothetical fresh placement
    /// that was feasible but not enumerated server-by-server.
    pub members: Vec<u32>,
    /// Warm reuse of an intact idle gang (no weight load).
    pub reuse: bool,
    /// Predicted duration (init + exec) of the attempt.
    pub predicted: f64,
    /// Cold-start bit: at least one member must load weights.
    pub cold: bool,
}

impl Candidate {
    fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("m", self.members.iter().map(|&m| m as u64).collect::<Vec<u64>>());
        v.set("reuse", self.reuse);
        v.set("pred", self.predicted);
        v.set("cold", self.cold);
        v
    }

    fn from_json(v: &Value) -> anyhow::Result<Candidate> {
        let members = v
            .req("m")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("bad candidate members"))?
            .iter()
            .map(|x| {
                x.as_f64()
                    .map(|f| f as u32)
                    .ok_or_else(|| anyhow::anyhow!("bad candidate member id"))
            })
            .collect::<anyhow::Result<_>>()?;
        Ok(Candidate {
            members,
            reuse: v.req("reuse")?.as_bool().unwrap_or(false),
            predicted: v
                .req("pred")?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("bad candidate pred"))?,
            cold: v.req("cold")?.as_bool().unwrap_or(false),
        })
    }
}

/// How a recorded decision's task left the system.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OutcomeStatus {
    /// The task completed (this or a sibling retry/backup attempt won).
    Completed,
    /// The task was dropped after exhausting its retry budget.
    Dropped,
}

impl OutcomeStatus {
    fn name(&self) -> &'static str {
        match self {
            OutcomeStatus::Completed => "completed",
            OutcomeStatus::Dropped => "dropped",
        }
    }
}

/// The realized outcome joined back onto a decision by task id.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Outcome {
    pub status: OutcomeStatus,
    /// Realized response latency (arrival → resolution).
    pub response: f64,
    /// Realized duration of the winning attempt (0 for drops).
    pub duration: f64,
    pub quality: f64,
    /// Whether the deadline was met; `None` for deadline-less tasks.
    pub deadline_met: Option<bool>,
    /// The winning attempt paid a cold start.
    pub cold: bool,
    /// A speculative backup won the race.
    pub spec_win: bool,
}

impl Outcome {
    fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("status", self.status.name());
        v.set("response", self.response);
        v.set("duration", self.duration);
        v.set("quality", self.quality);
        if let Some(m) = self.deadline_met {
            v.set("deadline_met", m);
        }
        v.set("cold", self.cold);
        v.set("spec_win", self.spec_win);
        v
    }

    fn from_json(v: &Value) -> anyhow::Result<Outcome> {
        let status = match v.req("status")?.as_str() {
            Some("completed") => OutcomeStatus::Completed,
            Some("dropped") => OutcomeStatus::Dropped,
            other => anyhow::bail!("unknown outcome status {other:?}"),
        };
        let f = |key: &str| -> anyhow::Result<f64> {
            v.req(key)?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("bad outcome field '{key}'"))
        };
        Ok(Outcome {
            status,
            response: f("response")?,
            duration: f("duration")?,
            quality: f("quality")?,
            deadline_met: v.get("deadline_met").and_then(Value::as_bool),
            cold: v.get("cold").and_then(Value::as_bool).unwrap_or(false),
            spec_win: v.get("spec_win").and_then(Value::as_bool).unwrap_or(false),
        })
    }
}

/// One recorded dispatch decision. `state`/`action` use the exact RL
/// encodings (`EdgeEnv::state`, the Eq. 8 action layout), so a ledger
/// doubles as offline experience. `outcome` is `None` while the task is
/// still in flight (or if the episode ended first).
#[derive(Clone, Debug, PartialEq)]
pub struct DecisionRecord {
    /// Monotone per-recorder sequence number (ring-eviction stable).
    pub seq: u64,
    /// Episode tag, stamped by the sweep driver before shard merge.
    pub episode: u64,
    /// Simulated time of the decision.
    pub t: f64,
    /// Recording policy label ("head-first", "greedy", "aware", ...).
    pub policy: String,
    pub task: u64,
    pub tenant: Option<u32>,
    /// Prior kill count of this task when the decision was made.
    pub attempt: u32,
    /// Queue slot the chosen task occupied.
    pub slot: usize,
    /// Inference steps chosen.
    pub steps: u32,
    /// Waiting time already accrued at the decision instant.
    pub waiting: f64,
    /// Absolute deadline, if the task has one.
    pub deadline: Option<f64>,
    /// The observed state snapshot (`EdgeEnv::state` layout).
    pub state: Vec<f32>,
    /// The action in the Eq. 8 layout `[a_c, a_s, scores...]`
    /// (synthesized one-hot for heuristic dispatch paths).
    pub action: Vec<f32>,
    /// Feasible candidate set at decision time.
    pub candidates: Vec<Candidate>,
    /// Index of the dispatched candidate in `candidates`.
    pub chosen: usize,
    /// Immediate reward booked for the dispatch (Eq. 10 semantics).
    pub reward: f64,
    pub outcome: Option<Outcome>,
}

impl DecisionRecord {
    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("seq", self.seq);
        v.set("ep", self.episode);
        v.set("t", self.t);
        v.set("policy", self.policy.as_str());
        v.set("task", self.task);
        if let Some(tn) = self.tenant {
            v.set("tenant", tn as u64);
        }
        v.set("attempt", self.attempt as u64);
        v.set("slot", self.slot as u64);
        v.set("steps", self.steps as u64);
        v.set("wait", self.waiting);
        if let Some(d) = self.deadline {
            v.set("deadline", d);
        }
        v.set("state", self.state.clone());
        v.set("action", self.action.clone());
        v.set(
            "cands",
            self.candidates.iter().map(Candidate::to_json).collect::<Vec<Value>>(),
        );
        v.set("chosen", self.chosen as u64);
        v.set("reward", self.reward);
        if let Some(o) = &self.outcome {
            v.set("outcome", o.to_json());
        }
        v
    }

    pub fn from_json(v: &Value) -> anyhow::Result<DecisionRecord> {
        let f = |key: &str| -> anyhow::Result<f64> {
            v.req(key)?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("bad decision field '{key}'"))
        };
        let floats = |key: &str| -> anyhow::Result<Vec<f32>> {
            v.req(key)?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("bad decision array '{key}'"))?
                .iter()
                .map(|x| {
                    x.as_f64()
                        .map(|f| f as f32)
                        .ok_or_else(|| anyhow::anyhow!("bad float in '{key}'"))
                })
                .collect()
        };
        Ok(DecisionRecord {
            seq: f("seq")? as u64,
            episode: f("ep")? as u64,
            t: f("t")?,
            policy: v
                .req("policy")?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("bad policy"))?
                .to_string(),
            task: f("task")? as u64,
            tenant: v.get("tenant").and_then(Value::as_f64).map(|x| x as u32),
            attempt: f("attempt")? as u32,
            slot: f("slot")? as usize,
            steps: f("steps")? as u32,
            waiting: f("wait")?,
            deadline: v.get("deadline").and_then(Value::as_f64),
            state: floats("state")?,
            action: floats("action")?,
            candidates: v
                .req("cands")?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("bad cands"))?
                .iter()
                .map(Candidate::from_json)
                .collect::<anyhow::Result<_>>()?,
            chosen: f("chosen")? as usize,
            reward: f("reward")?,
            outcome: match v.get("outcome") {
                Some(o) => Some(Outcome::from_json(o)?),
                None => None,
            },
        })
    }

    /// Best predicted completion over the feasible candidate set.
    pub fn best_predicted(&self) -> Option<f64> {
        self.candidates.iter().map(|c| c.predicted).fold(None, |acc, p| match acc {
            Some(a) if a <= p => Some(a),
            _ => Some(p),
        })
    }

    /// Hindsight-oracle response: the better of the realized response and
    /// the best candidate's predicted completion (from the same waiting
    /// time). The floor at the realized value makes regret non-negative
    /// by construction — the chosen candidate's realized outcome is
    /// itself feasible, so the oracle can never be beaten by reality.
    pub fn oracle_response(&self) -> Option<f64> {
        let out = self.outcome.as_ref()?;
        if out.status != OutcomeStatus::Completed {
            return None;
        }
        let best = self.best_predicted()?;
        Some((self.waiting + best).min(out.response))
    }

    /// Realized minus oracle response (≥ 0); `None` until the task
    /// completes.
    pub fn regret(&self) -> Option<f64> {
        let out = self.outcome.as_ref()?;
        if out.status != OutcomeStatus::Completed {
            return None;
        }
        Some(out.response - self.oracle_response()?)
    }

    /// Deadline flip: the policy's dispatch missed the deadline but the
    /// hindsight oracle's best candidate would have met it.
    pub fn deadline_flip(&self) -> bool {
        let (Some(d), Some(out)) = (self.deadline, self.outcome.as_ref()) else {
            return false;
        };
        if out.deadline_met != Some(false) {
            return false;
        }
        match self.best_predicted() {
            Some(best) => self.t + best <= d,
            None => false,
        }
    }
}

/// Bounded ring of decision records with eviction accounting and
/// bit-exact JSONL round trips — the `eat-decisions-v1` document.
#[derive(Clone, Debug)]
pub struct DecisionLedger {
    cap: usize,
    records: VecDeque<DecisionRecord>,
    evicted: u64,
}

impl DecisionLedger {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0, "decision ledger capacity must be > 0");
        DecisionLedger {
            cap,
            records: VecDeque::new(),
            evicted: 0,
        }
    }

    /// Default capacity: one record per dispatch attempt; preset episodes
    /// stay far below this.
    pub fn default_capacity() -> usize {
        1 << 16
    }

    pub fn push(&mut self, rec: DecisionRecord) {
        self.records.push_back(rec);
        if self.records.len() > self.cap {
            self.records.pop_front();
            self.evicted += 1;
        }
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn evicted(&self) -> u64 {
        self.evicted
    }

    pub fn records(&self) -> impl Iterator<Item = &DecisionRecord> {
        self.records.iter()
    }

    /// Find a surviving record by sequence number (ring-eviction aware).
    pub fn get_mut(&mut self, seq: u64) -> Option<&mut DecisionRecord> {
        let first = self.records.front()?.seq;
        let idx = seq.checked_sub(first)? as usize;
        self.records.get_mut(idx)
    }

    /// Stamp every surviving record with an episode tag (sweep drivers
    /// call this per shard before merging).
    pub fn tag_episode(&mut self, ep: u64) {
        for r in self.records.iter_mut() {
            r.episode = ep;
        }
    }

    /// Append another shard's records in order. Slot-order folding over
    /// `par::map_cells` output makes the merged ledger byte-identical for
    /// any thread count; the merged ring keeps `self`'s capacity and
    /// re-evicts (counted) past it.
    pub fn merge(&mut self, other: &DecisionLedger) {
        self.evicted += other.evicted;
        for r in other.records.iter().cloned() {
            self.push(r);
        }
    }

    /// JSONL export: a meta line (`schema`, `records`, `evicted`), then
    /// one record per line, oldest first. F64 fields round-trip
    /// bit-exactly (shortest-round-trip writer).
    pub fn to_jsonl(&self) -> String {
        let mut meta = Value::obj();
        meta.set("schema", schema::DECISIONS)
            .set("records", self.records.len())
            .set("evicted", self.evicted);
        let mut out = meta.to_json();
        out.push('\n');
        for r in &self.records {
            out.push_str(&r.to_json().to_json());
            out.push('\n');
        }
        out
    }

    pub fn write_jsonl(&self, path: &str) -> anyhow::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.to_jsonl())?;
        Ok(())
    }

    /// Parse an `eat-decisions-v1` JSONL document. Blank lines are
    /// skipped; a foreign schema is rejected.
    pub fn parse_jsonl(text: &str) -> anyhow::Result<DecisionLedger> {
        let mut records: VecDeque<DecisionRecord> = VecDeque::new();
        let mut evicted = 0u64;
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let v = json::parse(line)
                .map_err(|e| anyhow::anyhow!("decisions line {}: {e}", lineno + 1))?;
            if let Some(schema) = v.get("schema").and_then(Value::as_str) {
                anyhow::ensure!(
                    schema == self::schema::DECISIONS,
                    "decisions line {}: unsupported schema '{schema}'",
                    lineno + 1
                );
                evicted = v
                    .get("evicted")
                    .and_then(Value::as_f64)
                    .map(|x| x as u64)
                    .unwrap_or(0);
                continue;
            }
            records.push_back(
                DecisionRecord::from_json(&v)
                    .map_err(|e| anyhow::anyhow!("decisions line {}: {e}", lineno + 1))?,
            );
        }
        Ok(DecisionLedger {
            cap: records.len().max(1),
            records,
            evicted,
        })
    }
}

/// The live recorder threaded through `EdgeEnv`: a ledger plus the
/// pending-join table (task id → unresolved decision seqs, for the
/// deferred fault-path completions).
#[derive(Clone, Debug)]
pub struct DecisionRecorder {
    policy: String,
    ledger: DecisionLedger,
    next_seq: u64,
    pending: std::collections::BTreeMap<u64, Vec<u64>>,
}

impl DecisionRecorder {
    pub fn new(policy: &str, cap: usize) -> Self {
        DecisionRecorder {
            policy: policy.to_string(),
            ledger: DecisionLedger::new(cap),
            next_seq: 0,
            pending: std::collections::BTreeMap::new(),
        }
    }

    pub fn policy(&self) -> &str {
        &self.policy
    }

    pub fn ledger(&self) -> &DecisionLedger {
        &self.ledger
    }

    /// Record a decision (stamping its seq and policy); returns the seq
    /// for a later outcome join.
    pub fn record(&mut self, mut rec: DecisionRecord) -> u64 {
        let seq = self.next_seq;
        self.next_seq += 1;
        rec.seq = seq;
        rec.policy = self.policy.clone();
        self.ledger.push(rec);
        seq
    }

    /// Register a deferred join: the decision's outcome is unknown until
    /// the fault subsystem resolves the task.
    pub fn defer(&mut self, task: u64, seq: u64) {
        self.pending.entry(task).or_default().push(seq);
    }

    /// Fill a single decision's outcome immediately (fault-free path:
    /// completion is certain at dispatch).
    pub fn resolve_now(&mut self, seq: u64, outcome: Outcome) {
        if let Some(rec) = self.ledger.get_mut(seq) {
            rec.outcome = Some(outcome);
        }
    }

    /// Resolve every pending decision of `task` with the realized
    /// outcome (all attempts of a task share its task-level resolution).
    /// Joins onto evicted records are silently absorbed — the ledger's
    /// eviction count reports the loss.
    pub fn resolve_task(&mut self, task: u64, outcome: Outcome) {
        if let Some(seqs) = self.pending.remove(&task) {
            for seq in seqs {
                if let Some(rec) = self.ledger.get_mut(seq) {
                    rec.outcome = Some(outcome);
                }
            }
        }
    }

    /// Unresolved (still in-flight) decision count.
    pub fn pending_len(&self) -> usize {
        self.pending.values().map(Vec::len).sum()
    }

    /// Detach the ledger; decisions still pending stay `outcome: None`
    /// and are reported by the analyzer as in-flight.
    pub fn into_ledger(self) -> DecisionLedger {
        self.ledger
    }
}

/// Nearest-rank percentile over a sorted slice.
fn pctl(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((q / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// Regret distribution for one (policy, tenant) group. `tenant` is the
/// label `"all"` for the aggregate rows, `"-"` for untenanted tasks.
#[derive(Clone, Debug)]
pub struct RegretGroup {
    pub policy: String,
    pub tenant: String,
    pub count: usize,
    pub mean: f64,
    pub p50: f64,
    pub p99: f64,
    pub max: f64,
    pub deadline_flips: usize,
}

/// Predicted-vs-realized latency calibration for one slice.
#[derive(Clone, Debug)]
pub struct CalibRow {
    pub label: String,
    pub count: usize,
    pub mean_predicted: f64,
    pub mean_realized: f64,
    /// Percentiles of realized/predicted duration ratios.
    pub ratio_p50: f64,
    pub ratio_p99: f64,
}

/// Full hindsight analysis of a decision ledger.
#[derive(Clone, Debug)]
pub struct DecisionAnalysis {
    pub records: usize,
    pub completed: usize,
    pub dropped: usize,
    /// Decisions with no joined outcome (episode ended with the task in
    /// flight, or the join landed on an evicted record).
    pub inflight: usize,
    pub evicted: u64,
    /// Regret groups: an `("all","all")` aggregate first, then per
    /// policy, then per (policy, tenant).
    pub groups: Vec<RegretGroup>,
    pub calibration: Vec<CalibRow>,
    /// Cold-start confusion counts (predicted vs realized):
    /// [pred-cold & real-cold, pred-cold & real-warm,
    ///  pred-warm & real-cold, pred-warm & real-warm].
    pub cold_confusion: [usize; 4],
    /// Integrity violations (malformed chosen index, non-finite or
    /// non-positive predictions, negative regret, unaccounted joins).
    pub violations: Vec<String>,
}

impl DecisionAnalysis {
    /// Median regret over all completed decisions (the aggregate group).
    pub fn median_regret(&self) -> f64 {
        self.groups.first().map_or(0.0, |g| g.p50)
    }

    /// Non-zero-exit gate: every decision must join or be accounted as
    /// in-flight/evicted, and the regret books must balance.
    pub fn check_books(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.violations.is_empty(),
            "decision ledger integrity violations:\n  {}",
            self.violations.join("\n  ")
        );
        Ok(())
    }

    pub fn to_json(&self, source: &str) -> Value {
        let mut v = Value::obj();
        v.set("schema", schema::DECISIONS_ANALYSIS);
        v.set("source", source);
        v.set("records", self.records);
        v.set("completed", self.completed);
        v.set("dropped", self.dropped);
        v.set("inflight", self.inflight);
        v.set("evicted", self.evicted);
        v.set("median_regret", self.median_regret());
        let groups: Vec<Value> = self
            .groups
            .iter()
            .map(|g| {
                let mut gv = Value::obj();
                gv.set("policy", g.policy.as_str());
                gv.set("tenant", g.tenant.as_str());
                gv.set("count", g.count);
                gv.set("mean", g.mean);
                gv.set("p50", g.p50);
                gv.set("p99", g.p99);
                gv.set("max", g.max);
                gv.set("deadline_flips", g.deadline_flips);
                gv
            })
            .collect();
        v.set("regret", groups);
        let calib: Vec<Value> = self
            .calibration
            .iter()
            .map(|c| {
                let mut cv = Value::obj();
                cv.set("label", c.label.as_str());
                cv.set("count", c.count);
                cv.set("mean_predicted", c.mean_predicted);
                cv.set("mean_realized", c.mean_realized);
                cv.set("ratio_p50", c.ratio_p50);
                cv.set("ratio_p99", c.ratio_p99);
                cv
            })
            .collect();
        v.set("calibration", calib);
        let mut cc = Value::obj();
        cc.set("pred_cold_real_cold", self.cold_confusion[0]);
        cc.set("pred_cold_real_warm", self.cold_confusion[1]);
        cc.set("pred_warm_real_cold", self.cold_confusion[2]);
        cc.set("pred_warm_real_warm", self.cold_confusion[3]);
        v.set("cold_confusion", cc);
        v.set("violations", self.violations.clone());
        v
    }

    pub fn render(&self, source: &str) -> String {
        use crate::util::table::{f, Table};
        let mut out = String::new();
        out.push_str(&format!(
            "decision ledger {source}: {} records ({} completed, {} dropped, {} in-flight, {} evicted)\n\n",
            self.records, self.completed, self.dropped, self.inflight, self.evicted
        ));
        let mut t = Table::new(
            "Hindsight regret (s)",
            &["policy", "tenant", "n", "mean", "p50", "p99", "max", "ddl flips"],
        );
        for g in &self.groups {
            t.row(vec![
                g.policy.clone(),
                g.tenant.clone(),
                g.count.to_string(),
                f(g.mean, 2),
                f(g.p50, 2),
                f(g.p99, 2),
                f(g.max, 2),
                g.deadline_flips.to_string(),
            ]);
        }
        out.push_str(&t.render());
        out.push('\n');
        let mut c = Table::new(
            "Predicted vs realized duration",
            &["slice", "n", "mean pred", "mean real", "ratio p50", "ratio p99"],
        );
        for row in &self.calibration {
            c.row(vec![
                row.label.clone(),
                row.count.to_string(),
                f(row.mean_predicted, 2),
                f(row.mean_realized, 2),
                f(row.ratio_p50, 3),
                f(row.ratio_p99, 3),
            ]);
        }
        out.push_str(&c.render());
        out.push('\n');
        let [cc, cw, wc, ww] = self.cold_confusion;
        out.push_str(&format!(
            "cold-start calibration: predicted-cold {} ({} realized cold, {} warm), predicted-warm {} ({} realized cold, {} warm)\n",
            cc + cw,
            cc,
            cw,
            wc + ww,
            wc,
            ww
        ));
        if !self.violations.is_empty() {
            out.push_str(&format!(
                "\nINTEGRITY VIOLATIONS ({}):\n  {}\n",
                self.violations.len(),
                self.violations.join("\n  ")
            ));
        }
        out
    }
}

fn group_stats(policy: &str, tenant: &str, regrets: &mut Vec<f64>, flips: usize) -> RegretGroup {
    regrets.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = regrets.len();
    let mean = if n == 0 { 0.0 } else { regrets.iter().sum::<f64>() / n as f64 };
    RegretGroup {
        policy: policy.to_string(),
        tenant: tenant.to_string(),
        count: n,
        mean,
        p50: pctl(regrets, 50.0),
        p99: pctl(regrets, 99.0),
        max: regrets.last().copied().unwrap_or(0.0),
        deadline_flips: flips,
    }
}

/// Analyze a parsed ledger: join accounting, hindsight regret by
/// policy/tenant, deadline flips, calibration, and integrity checks.
pub fn analyze(ledger: &DecisionLedger) -> DecisionAnalysis {
    let mut completed = 0usize;
    let mut dropped = 0usize;
    let mut inflight = 0usize;
    let mut violations: Vec<String> = Vec::new();
    // (policy, tenant-label) → (regrets, flips); BTreeMap keeps the
    // report order deterministic.
    use std::collections::BTreeMap;
    let mut by_key: BTreeMap<(String, String), (Vec<f64>, usize)> = BTreeMap::new();
    let mut all: (Vec<f64>, usize) = (Vec::new(), 0);
    let mut calib: BTreeMap<&'static str, (Vec<f64>, f64, f64)> = BTreeMap::new();
    let mut confusion = [0usize; 4];
    for rec in ledger.records() {
        if rec.candidates.is_empty() {
            violations.push(format!("decision seq {} has an empty candidate set", rec.seq));
            continue;
        }
        if rec.chosen >= rec.candidates.len() {
            violations.push(format!(
                "decision seq {}: chosen index {} out of range ({} candidates)",
                rec.seq,
                rec.chosen,
                rec.candidates.len()
            ));
            continue;
        }
        if rec.candidates.iter().any(|c| !c.predicted.is_finite() || c.predicted <= 0.0) {
            violations.push(format!(
                "decision seq {}: non-finite or non-positive predicted duration",
                rec.seq
            ));
            continue;
        }
        let chosen = &rec.candidates[rec.chosen];
        match &rec.outcome {
            None => inflight += 1,
            Some(out) if out.status == OutcomeStatus::Dropped => dropped += 1,
            Some(out) => {
                completed += 1;
                if !out.response.is_finite() || out.response < 0.0 {
                    violations.push(format!(
                        "decision seq {}: non-finite or negative realized response",
                        rec.seq
                    ));
                    continue;
                }
                let regret = rec.regret().expect("completed outcome has a regret");
                let oracle = rec.oracle_response().expect("completed outcome has an oracle");
                if regret < 0.0 || oracle > out.response {
                    violations.push(format!(
                        "decision seq {}: regret books imbalance (regret {regret}, oracle {oracle}, realized {})",
                        rec.seq, out.response
                    ));
                    continue;
                }
                let flip = rec.deadline_flip() as usize;
                all.0.push(regret);
                all.1 += flip;
                let tn = rec.tenant.map_or_else(|| "-".to_string(), |t| t.to_string());
                let e = by_key.entry((rec.policy.clone(), "all".to_string())).or_default();
                e.0.push(regret);
                e.1 += flip;
                let e = by_key.entry((rec.policy.clone(), tn)).or_default();
                e.0.push(regret);
                e.1 += flip;
                // Calibration: the chosen candidate's prediction against
                // the winning attempt's realized duration.
                if out.duration > 0.0 {
                    let slice = if chosen.cold { "cold" } else { "warm" };
                    for key in ["all", slice] {
                        let c = calib.entry(key).or_default();
                        c.0.push(out.duration / chosen.predicted);
                        c.1 += chosen.predicted;
                        c.2 += out.duration;
                    }
                }
                confusion[match (chosen.cold, out.cold) {
                    (true, true) => 0,
                    (true, false) => 1,
                    (false, true) => 2,
                    (false, false) => 3,
                }] += 1;
            }
        }
    }
    if completed + dropped + inflight + violations.len() < ledger.len() {
        violations.push(format!(
            "join books imbalance: {} records vs {} accounted",
            ledger.len(),
            completed + dropped + inflight
        ));
    }
    let mut groups = vec![group_stats("all", "all", &mut all.0, all.1)];
    for ((policy, tenant), (mut regrets, flips)) in by_key {
        groups.push(group_stats(&policy, &tenant, &mut regrets, flips));
    }
    let calibration = calib
        .into_iter()
        .map(|(label, (mut ratios, pred_sum, real_sum))| {
            ratios.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let n = ratios.len();
            CalibRow {
                label: label.to_string(),
                count: n,
                mean_predicted: if n == 0 { 0.0 } else { pred_sum / n as f64 },
                mean_realized: if n == 0 { 0.0 } else { real_sum / n as f64 },
                ratio_p50: pctl(&ratios, 50.0),
                ratio_p99: pctl(&ratios, 99.0),
            }
        })
        .collect();
    DecisionAnalysis {
        records: ledger.len(),
        completed,
        dropped,
        inflight,
        evicted: ledger.evicted(),
        groups,
        calibration,
        cold_confusion: confusion,
        violations,
    }
}

/// Export a ledger as `eat-experience-v1` JSONL: a meta line
/// (`schema`, `state_dim`, `action_dim`, `tuples`), then one
/// `(s, a, r, s2, done)` tuple per line — the replay-buffer format of
/// `rl::replay::ReplayBuffer::from_experience_jsonl`. Tuples follow
/// ledger order within each (episode, policy) group; `s2` is the next
/// decision's observed state and the last decision of a group closes
/// with `done = true` (its own state echoed as `s2`, the standard
/// terminal-transition convention).
pub fn export_experience(ledger: &DecisionLedger) -> anyhow::Result<String> {
    let recs: Vec<&DecisionRecord> = ledger.records().collect();
    anyhow::ensure!(!recs.is_empty(), "cannot export experience from an empty ledger");
    let state_dim = recs[0].state.len();
    let action_dim = recs[0].action.len();
    for r in &recs {
        anyhow::ensure!(
            r.state.len() == state_dim && r.action.len() == action_dim,
            "mixed state/action dims in ledger (seq {}): {}x{} vs {state_dim}x{action_dim}",
            r.seq,
            r.state.len(),
            r.action.len()
        );
    }
    let mut meta = Value::obj();
    meta.set("schema", schema::EXPERIENCE)
        .set("state_dim", state_dim)
        .set("action_dim", action_dim)
        .set("tuples", recs.len());
    let mut out = meta.to_json();
    out.push('\n');
    for (i, r) in recs.iter().enumerate() {
        let next = recs
            .get(i + 1)
            .copied()
            .filter(|o| o.episode == r.episode && o.policy == r.policy);
        let done = next.is_none();
        let s2 = next.map_or(&r.state, |n| &n.state);
        let mut v = Value::obj();
        v.set("s", r.state.clone());
        v.set("a", r.action.clone());
        v.set("r", r.reward);
        v.set("s2", s2.clone());
        v.set("done", done);
        out.push_str(&v.to_json());
        out.push('\n');
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(seq: u64, t: f64, response: f64, best_pred: f64) -> DecisionRecord {
        DecisionRecord {
            seq,
            episode: 0,
            t,
            policy: "test".to_string(),
            task: seq,
            tenant: if seq % 2 == 0 { Some(0) } else { None },
            attempt: 0,
            slot: 0,
            steps: 30,
            waiting: 1.5,
            deadline: Some(t + 100.0),
            state: vec![0.25, 0.5, 0.75],
            action: vec![-1.0, 0.0, 1.0, 0.0],
            candidates: vec![
                Candidate { members: vec![0, 1], reuse: true, predicted: best_pred, cold: false },
                Candidate {
                    members: vec![],
                    reuse: false,
                    predicted: best_pred + 30.0,
                    cold: true,
                },
            ],
            chosen: 0,
            reward: 0.5,
            outcome: Some(Outcome {
                status: OutcomeStatus::Completed,
                response,
                duration: response - 1.5,
                quality: 0.25,
                deadline_met: Some(true),
                cold: false,
                spec_win: false,
            }),
        }
    }

    #[test]
    fn ring_evicts_oldest_and_counts() {
        let mut l = DecisionLedger::new(3);
        for i in 0..5u64 {
            l.push(rec(i, i as f64, 20.0, 10.0));
        }
        assert_eq!(l.len(), 3);
        assert_eq!(l.evicted(), 2);
        let seqs: Vec<u64> = l.records().map(|r| r.seq).collect();
        assert_eq!(seqs, vec![2, 3, 4]);
        // Seq addressing survives eviction.
        assert!(l.get_mut(1).is_none());
        assert_eq!(l.get_mut(3).unwrap().seq, 3);
    }

    #[test]
    fn jsonl_round_trip_is_bit_exact() {
        let mut l = DecisionLedger::new(16);
        let mut a = rec(0, 0.1 + 0.2, 40.150000000000006, 33.07218471984863);
        a.state = vec![1.0f32 / 3.0, 0.1, -0.7];
        l.push(a);
        let mut b = rec(1, 2.5, 11.0, 9.5);
        b.outcome = None;
        l.push(b);
        let mut c = rec(2, 3.5, 80.0, 9.5);
        c.outcome = Some(Outcome {
            status: OutcomeStatus::Dropped,
            response: 80.0,
            duration: 0.0,
            quality: 0.0,
            deadline_met: Some(false),
            cold: true,
            spec_win: false,
        });
        l.push(c);
        let text = l.to_jsonl();
        assert!(text.lines().next().unwrap().contains("\"schema\":\"eat-decisions-v1\""));
        let back = DecisionLedger::parse_jsonl(&text).unwrap();
        assert_eq!(back.len(), l.len());
        for (x, y) in l.records().zip(back.records()) {
            assert_eq!(x.t.to_bits(), y.t.to_bits());
            assert_eq!(x, y, "record did not round-trip");
        }
        // Round trip again: byte-identical.
        assert_eq!(back.to_jsonl(), text);
    }

    #[test]
    fn foreign_schema_is_rejected() {
        assert!(DecisionLedger::parse_jsonl("{\"schema\":\"eat-trace-v1\"}").is_err());
    }

    #[test]
    fn merge_is_fold_order_deterministic() {
        let shard = |ep: u64| {
            let mut l = DecisionLedger::new(8);
            for i in 0..3u64 {
                let mut r = rec(i, i as f64, 20.0 + ep as f64, 10.0);
                r.episode = ep;
                l.push(r);
            }
            l
        };
        let mut merged = DecisionLedger::new(DecisionLedger::default_capacity());
        for ep in 0..4u64 {
            merged.merge(&shard(ep));
        }
        assert_eq!(merged.len(), 12);
        let eps: Vec<u64> = merged.records().map(|r| r.episode).collect();
        assert_eq!(eps, vec![0, 0, 0, 1, 1, 1, 2, 2, 2, 3, 3, 3]);
    }

    #[test]
    fn recorder_joins_now_and_deferred() {
        let mut r = DecisionRecorder::new("head-first", 16);
        let mut d = rec(99, 0.0, 0.0, 10.0);
        d.outcome = None;
        d.task = 7;
        let s0 = r.record(d.clone());
        assert_eq!(s0, 0);
        assert_eq!(r.ledger().records().next().unwrap().policy, "head-first");
        let out = Outcome {
            status: OutcomeStatus::Completed,
            response: 12.0,
            duration: 10.5,
            quality: 0.2,
            deadline_met: None,
            cold: false,
            spec_win: false,
        };
        r.resolve_now(s0, out);
        assert_eq!(r.ledger().records().next().unwrap().outcome, Some(out));
        // Deferred join path: two attempts of one task resolve together.
        let s1 = r.record(d.clone());
        let s2 = r.record(d);
        r.defer(7, s1);
        r.defer(7, s2);
        assert_eq!(r.pending_len(), 2);
        r.resolve_task(7, out);
        assert_eq!(r.pending_len(), 0);
        let l = r.into_ledger();
        assert!(l.records().all(|rc| rc.outcome == Some(out)));
    }

    #[test]
    fn regret_is_nonnegative_and_oracle_bounded() {
        // Policy beaten by the oracle: realized 40 vs predicted-best 10.
        let r = rec(0, 5.0, 40.0, 10.0);
        assert_eq!(r.oracle_response(), Some(11.5));
        assert_eq!(r.regret(), Some(40.0 - 11.5));
        // Realized better than every prediction: oracle floors at
        // realized, regret exactly 0.
        let r2 = rec(1, 5.0, 5.0, 10.0);
        assert_eq!(r2.oracle_response(), Some(5.0));
        assert_eq!(r2.regret(), Some(0.0));
    }

    #[test]
    fn deadline_flip_detected() {
        let mut r = rec(0, 0.0, 200.0, 10.0);
        r.deadline = Some(50.0);
        r.outcome.as_mut().unwrap().deadline_met = Some(false);
        // Oracle completion 0 + 10 <= 50: the best candidate met it.
        assert!(r.deadline_flip());
        // Oracle could not have met it either.
        r.candidates[0].predicted = 60.0;
        r.candidates[1].predicted = 90.0;
        assert!(!r.deadline_flip());
    }

    #[test]
    fn analysis_accounts_every_record_and_balances() {
        let mut l = DecisionLedger::new(16);
        l.push(rec(0, 0.0, 40.0, 10.0));
        l.push(rec(1, 1.0, 12.0, 10.0));
        let mut infl = rec(2, 2.0, 0.0, 10.0);
        infl.outcome = None;
        l.push(infl);
        let mut drop = rec(3, 3.0, 90.0, 10.0);
        drop.outcome.as_mut().unwrap().status = OutcomeStatus::Dropped;
        l.push(drop);
        let a = analyze(&l);
        assert_eq!((a.records, a.completed, a.dropped, a.inflight), (4, 2, 1, 1));
        a.check_books().unwrap();
        assert!(a.groups[0].p50 >= 0.0);
        assert_eq!(a.groups[0].policy, "all");
        // Per-policy and per-tenant rows exist.
        assert!(a.groups.iter().any(|g| g.policy == "test" && g.tenant == "all"));
        assert!(a.groups.iter().any(|g| g.policy == "test" && g.tenant == "0"));
        assert!(a.groups.iter().any(|g| g.policy == "test" && g.tenant == "-"));
        let text = a.render("mem");
        assert!(text.contains("Hindsight regret"));
        assert!(text.contains("in-flight"));
    }

    #[test]
    fn corrupted_ledger_fails_books() {
        let mut l = DecisionLedger::new(4);
        let mut bad = rec(0, 0.0, 40.0, 10.0);
        bad.chosen = 9;
        l.push(bad);
        let a = analyze(&l);
        assert!(a.check_books().is_err());
        let mut l2 = DecisionLedger::new(4);
        let mut neg = rec(0, 0.0, 40.0, 10.0);
        neg.candidates[0].predicted = -1.0;
        l2.push(neg);
        assert!(analyze(&l2).check_books().is_err());
    }

    #[test]
    fn experience_export_round_trips_into_replay_buffer() {
        let mut l = DecisionLedger::new(16);
        for i in 0..5u64 {
            let mut r = rec(i, i as f64, 20.0 + i as f64, 10.0);
            r.episode = i / 3; // two episode groups: [0,1,2], [3,4]
            l.push(r);
        }
        let text = export_experience(&l).unwrap();
        let meta = text.lines().next().unwrap();
        assert!(meta.contains("\"schema\":\"eat-experience-v1\""), "{meta}");
        let buf = crate::rl::replay::ReplayBuffer::from_experience_jsonl(&text, 64).unwrap();
        assert_eq!(buf.len(), 5);
        // Terminal transitions close each episode group.
        let dones: Vec<bool> = text
            .lines()
            .skip(1)
            .map(|ln| json::parse(ln).unwrap().get("done").unwrap().as_bool().unwrap())
            .collect();
        assert_eq!(dones, vec![false, false, true, false, true]);
        let mut rng = crate::util::rng::Pcg64::seeded(5);
        let batch = buf.sample(4, &mut rng);
        assert_eq!(batch.size, 4);
    }
}
