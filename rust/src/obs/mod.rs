//! Observability: determinism-safe lifecycle tracing, fleet telemetry
//! time-series, SLO burn-rate monitoring, live metrics with a Prometheus
//! text-exposition endpoint, and a latency-decomposition analyzer.
//!
//! The paper's headline claim (up to 56% inference-latency reduction) is
//! only auditable if we can say *where* each task's latency came from:
//! queueing, cold start, execution, straggler slack, or retry rounds. The
//! simulator and the serving loop emit typed span events into a
//! [`trace::TraceRecorder`] (bounded ring buffer, allocation-free once
//! warm, JSONL export); [`analyze`] reconstructs per-task lifecycles from
//! a trace and decomposes every completed task's measured latency into
//! components that sum back to it bit-exactly. [`timeseries`] samples the
//! fleet at a fixed cadence (queue depth, residency churn, per-tenant
//! deadline hits/misses) into a bounded, mergeable `eat-timeseries-v1`
//! series; [`slo`] turns traces or series into per-tenant error-budget
//! burn-rate reports (`eat slo report`). [`decisions`] records every
//! dispatch decision — observed state, feasible candidate set with
//! predicted completions, chosen action, realized outcome — into a
//! mergeable `eat-decisions-v1` ledger that `eat decisions analyze`
//! turns into hindsight-regret reports and offline RL experience.
//! [`metrics`] is a small counter/gauge/histogram registry that
//! `eat serve --metrics-addr` exposes over plain TCP in the Prometheus
//! text format. [`schema`] is the central registry of `eat-*-vN` wire
//! schema names (the `schema` lint rule bans literals anywhere else).
//! [`log`] is the leveled stderr logger
//! (`EAT_LOG=warn|info|debug`, `--quiet`) that replaces the ad-hoc
//! progress `eprintln!`s.
//!
//! Nothing in this module touches an RNG stream: recording is observation
//! only, so every bit-exactness property (event core vs tick core, trace
//! replay, CRN pairing) holds with tracing on or off — pinned by tests in
//! `sim/env.rs`.

pub mod analyze;
pub mod decisions;
pub mod log;
pub mod metrics;
pub mod schema;
pub mod slo;
pub mod timeseries;
pub mod trace;

pub use analyze::{analyze, analyze_jsonl, Analysis, TaskDecomp};
pub use decisions::{DecisionLedger, DecisionRecord, DecisionRecorder};
pub use metrics::{MetricRegistry, MetricsServer};
pub use slo::{SloClass, SloOptions, SloReport};
pub use timeseries::{FleetSampler, FleetSeries};
pub use trace::{GangRef, SpanEvent, SpanKind, TraceRecorder};
