//! SLO error budgets and burn-rate monitoring (`eat slo report`).
//!
//! Every tenant class carries an attainment target (e.g. 0.9 = 90% of
//! outcomes inside the latency SLO), which defines an error budget: the
//! run may miss at most `(1 - target) × outcomes`. This module replays a
//! per-task trace (`eat-trace-v1` JSONL) or a fleet time series
//! (`eat-timeseries-v1` JSONL) on the *simulated* clock and reports, per
//! tenant: the spent budget, the maximum burn rate over fast and slow
//! tumbling windows (burn rate 1.0 = spending exactly the budget;
//! multi-window alerting à la SRE practice), and — when the budget runs
//! out — the simulated time at which it was exhausted.
//! [`SloReport::check`] fails when any tenant exhausted its budget, so
//! the command is CI-gateable by exit code.

use crate::obs::schema;
use crate::obs::trace::{SpanEvent, SpanKind, NO_TENANT};
use crate::obs::FleetSeries;
use crate::qos::TenantsConfig;
use crate::util::json::Value;
use crate::util::table::{f, Table};

/// One tenant's SLO contract for budget purposes.
#[derive(Clone, Debug)]
pub struct SloClass {
    pub name: String,
    /// Attainment target in (0, 1): the fraction of outcomes that must
    /// land inside the latency SLO.
    pub target: f64,
    /// Latency budget in simulated seconds (a completion slower than
    /// this is an error; used only for trace inputs — time series carry
    /// hits/misses pre-classified).
    pub latency_slo: f64,
}

impl SloClass {
    /// Classes from a tenants config, in registry order.
    pub fn from_config(cfg: &TenantsConfig) -> Vec<SloClass> {
        cfg.tenants
            .iter()
            .map(|t| SloClass {
                name: t.name.clone(),
                target: t.slo_target,
                latency_slo: t.latency_slo,
            })
            .collect()
    }
}

/// Fallback contract for untenanted traces and unknown tenant indices.
const DEFAULT_TARGET: f64 = 0.9;
const DEFAULT_LATENCY_SLO: f64 = 120.0;

/// (time, outcomes, errors) — one terminal event from a trace, or one
/// window from a time series.
type Bucket = (f64, u64, u64);

/// Per-tenant burn-rate summary.
#[derive(Clone, Debug)]
pub struct TenantBurn {
    pub name: String,
    pub target: f64,
    /// Terminal outcomes observed (completions + drops).
    pub outcomes: u64,
    /// Outcomes that missed: late completions and drops.
    pub errors: u64,
    /// Allowed errors: `(1 - target) × outcomes`.
    pub budget: f64,
    /// Fraction of the budget spent (`errors / budget`; 0 on an empty
    /// budget with no errors, infinite with errors).
    pub budget_spent: f64,
    pub max_fast_burn: f64,
    pub max_slow_burn: f64,
    /// Simulated time at which cumulative errors first exceeded the
    /// budget; `None` while the budget holds.
    pub exhausted_at: Option<f64>,
}

/// The full report over every tenant seen in the input.
#[derive(Clone, Debug)]
pub struct SloReport {
    pub tenants: Vec<TenantBurn>,
    pub fast_window: f64,
    pub slow_window: f64,
}

fn burn_over_windows(buckets: &[Bucket], window: f64, err_frac: f64) -> f64 {
    // Tumbling windows on the sim clock: bucket t lands in window
    // floor(t / window). Buckets arrive time-sorted.
    let mut max_burn = 0.0f64;
    let mut idx = u64::MAX;
    let (mut total, mut errors) = (0u64, 0u64);
    let mut flush = |total: u64, errors: u64, max_burn: &mut f64| {
        if total > 0 && err_frac > 0.0 {
            let burn = (errors as f64 / total as f64) / err_frac;
            if burn > *max_burn {
                *max_burn = burn;
            }
        }
    };
    for &(t, n, e) in buckets {
        let w = (t / window).floor() as u64;
        if w != idx {
            flush(total, errors, &mut max_burn);
            idx = w;
            total = 0;
            errors = 0;
        }
        total += n;
        errors += e;
    }
    flush(total, errors, &mut max_burn);
    max_burn
}

fn burn_for(name: &str, class: &SloClass, mut buckets: Vec<Bucket>, opt: &SloOptions) -> TenantBurn {
    buckets.sort_by(|a, b| a.0.total_cmp(&b.0));
    let outcomes: u64 = buckets.iter().map(|b| b.1).sum();
    let errors: u64 = buckets.iter().map(|b| b.2).sum();
    let err_frac = 1.0 - class.target;
    let budget = err_frac * outcomes as f64;
    let mut exhausted_at = None;
    let mut cum = 0u64;
    for &(t, _, e) in &buckets {
        cum += e;
        if cum as f64 > budget {
            exhausted_at = Some(t);
            break;
        }
    }
    let budget_spent = if budget > 0.0 {
        errors as f64 / budget
    } else if errors > 0 {
        f64::INFINITY
    } else {
        0.0
    };
    TenantBurn {
        name: name.to_string(),
        target: class.target,
        outcomes,
        errors,
        budget,
        budget_spent,
        max_fast_burn: burn_over_windows(&buckets, opt.fast_window, err_frac),
        max_slow_burn: burn_over_windows(&buckets, opt.slow_window, err_frac),
        exhausted_at,
    }
}

/// Windowing knobs for the burn-rate computation.
#[derive(Clone, Copy, Debug)]
pub struct SloOptions {
    pub fast_window: f64,
    pub slow_window: f64,
}

impl Default for SloOptions {
    fn default() -> Self {
        SloOptions { fast_window: 60.0, slow_window: 300.0 }
    }
}

fn class_for(classes: &[SloClass], tenant: u32) -> SloClass {
    if tenant == NO_TENANT {
        return SloClass {
            name: "all".to_string(),
            target: classes.first().map(|c| c.target).unwrap_or(DEFAULT_TARGET),
            latency_slo: classes
                .first()
                .map(|c| c.latency_slo)
                .unwrap_or(DEFAULT_LATENCY_SLO),
        };
    }
    classes.get(tenant as usize).cloned().unwrap_or(SloClass {
        name: format!("tenant-{tenant}"),
        target: DEFAULT_TARGET,
        latency_slo: DEFAULT_LATENCY_SLO,
    })
}

/// Build the report from per-task trace events. A terminal outcome is a
/// `completed` (error when `response > latency_slo`) or a `dropped`
/// (always an error), timestamped at the event's simulated time.
pub fn report_from_trace(events: &[SpanEvent], classes: &[SloClass], opt: SloOptions) -> SloReport {
    // Group buckets per tenant id, in first-seen order for stable output.
    let mut order: Vec<u32> = Vec::new();
    let mut buckets: std::collections::BTreeMap<u32, Vec<Bucket>> =
        std::collections::BTreeMap::new();
    for ev in events {
        let class = class_for(classes, ev.tenant);
        let bucket = match &ev.kind {
            SpanKind::Completed { response, .. } => {
                Some((ev.t, 1, u64::from(*response > class.latency_slo)))
            }
            SpanKind::Dropped { .. } => Some((ev.t, 1, 1)),
            _ => None,
        };
        if let Some(b) = bucket {
            if !buckets.contains_key(&ev.tenant) {
                order.push(ev.tenant);
            }
            buckets.entry(ev.tenant).or_default().push(b);
        }
    }
    order.sort_unstable();
    let tenants = order
        .into_iter()
        .map(|tenant| {
            let class = class_for(classes, tenant);
            burn_for(&class.name, &class, buckets.remove(&tenant).unwrap(), &opt)
        })
        .collect();
    SloReport {
        tenants,
        fast_window: opt.fast_window,
        slow_window: opt.slow_window,
    }
}

/// Build the report from a fleet time series: each window contributes
/// one bucket per tenant (`hits + misses` outcomes, `misses` errors) at
/// the window's end time.
pub fn report_from_series(series: &FleetSeries, classes: &[SloClass], opt: SloOptions) -> SloReport {
    let names = series.tenants();
    let n = names.len();
    let mut per_tenant: Vec<Vec<Bucket>> = vec![Vec::new(); n.max(1)];
    for s in series.samples() {
        let t = (s.window + 1) as f64 * series.cadence();
        if n == 0 {
            // Untenanted series: pool hits/misses (both empty) — nothing
            // to report, but keep the shape.
            continue;
        }
        for i in 0..n {
            let hits = s.hits.get(i).copied().unwrap_or(0);
            let misses = s.misses.get(i).copied().unwrap_or(0);
            if hits + misses > 0 {
                per_tenant[i].push((t, hits + misses, misses));
            }
        }
    }
    let tenants = (0..n)
        .map(|i| {
            // Match the series tenant to a class by name first, then by
            // index, then fall back to defaults.
            let class = classes
                .iter()
                .find(|c| c.name == names[i])
                .cloned()
                .unwrap_or_else(|| class_for(classes, i as u32));
            burn_for(&names[i], &class, per_tenant[i].clone(), &opt)
        })
        .collect();
    SloReport {
        tenants,
        fast_window: opt.fast_window,
        slow_window: opt.slow_window,
    }
}

impl SloReport {
    /// True when any tenant ran out of error budget.
    pub fn exhausted(&self) -> bool {
        self.tenants.iter().any(|t| t.exhausted_at.is_some())
    }

    /// Non-zero-exit gate: errors when any tenant exhausted its budget.
    pub fn check(&self) -> anyhow::Result<()> {
        let exhausted: Vec<String> = self
            .tenants
            .iter()
            .filter_map(|t| {
                t.exhausted_at.map(|at| {
                    format!(
                        "{} (target {:.3}, {} errors / budget {:.1}, exhausted at t={:.1}s)",
                        t.name, t.target, t.errors, t.budget, at
                    )
                })
            })
            .collect();
        anyhow::ensure!(
            exhausted.is_empty(),
            "error budget exhausted: {}",
            exhausted.join("; ")
        );
        Ok(())
    }

    /// Human-readable table.
    pub fn render(&self, source: &str) -> String {
        let mut table = Table::new(
            &format!(
                "SLO burn-rate report: {source} (fast {}s / slow {}s windows)",
                self.fast_window, self.slow_window
            ),
            &[
                "tenant", "target", "outcomes", "errors", "budget", "spent%", "fast burn",
                "slow burn", "exhausted@",
            ],
        );
        for t in &self.tenants {
            table.row(vec![
                t.name.clone(),
                f(t.target, 3),
                format!("{}", t.outcomes),
                format!("{}", t.errors),
                f(t.budget, 1),
                if t.budget_spent.is_finite() {
                    f(t.budget_spent * 100.0, 1)
                } else {
                    "inf".to_string()
                },
                f(t.max_fast_burn, 2),
                f(t.max_slow_burn, 2),
                match t.exhausted_at {
                    Some(at) => f(at, 1),
                    None => "-".to_string(),
                },
            ]);
        }
        table.render()
    }

    /// Machine-readable document (`eat-slo-report-v1`).
    pub fn to_json(&self, source: &str) -> Value {
        let mut v = Value::obj();
        v.set("schema", schema::SLO_REPORT)
            .set("source", source)
            .set("fast_window", self.fast_window)
            .set("slow_window", self.slow_window)
            .set("exhausted", self.exhausted());
        let tenants: Vec<Value> = self
            .tenants
            .iter()
            .map(|t| {
                let mut o = Value::obj();
                o.set("tenant", t.name.clone())
                    .set("target", t.target)
                    .set("outcomes", t.outcomes)
                    .set("errors", t.errors)
                    .set("budget", t.budget)
                    .set("budget_spent", t.budget_spent)
                    .set("max_fast_burn", t.max_fast_burn)
                    .set("max_slow_burn", t.max_slow_burn);
                match t.exhausted_at {
                    Some(at) => o.set("exhausted_at", at),
                    None => o.set("exhausted_at", Value::Null),
                };
                o
            })
            .collect();
        v.set("tenants", Value::Arr(tenants));
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::DropReason;

    fn classes() -> Vec<SloClass> {
        vec![
            SloClass { name: "premium".into(), target: 0.9, latency_slo: 100.0 },
            SloClass { name: "batch".into(), target: 0.5, latency_slo: 100.0 },
        ]
    }

    fn completed(t: f64, task: u64, tenant: u32, response: f64) -> SpanEvent {
        SpanEvent {
            t,
            task,
            tenant,
            kind: SpanKind::Completed { response, start: t - response, speculative: false },
        }
    }

    fn dropped(t: f64, task: u64, tenant: u32) -> SpanEvent {
        SpanEvent {
            t,
            task,
            tenant,
            kind: SpanKind::Dropped { reason: DropReason::Admission },
        }
    }

    #[test]
    fn compliant_trace_keeps_its_budget() {
        // 20 premium outcomes, 1 late: error rate 5% < 10% budget.
        let mut evs: Vec<SpanEvent> =
            (0..19).map(|i| completed(10.0 + i as f64, i, 0, 50.0)).collect();
        evs.push(completed(40.0, 99, 0, 500.0));
        let rep = report_from_trace(&evs, &classes(), SloOptions::default());
        assert_eq!(rep.tenants.len(), 1);
        let t = &rep.tenants[0];
        assert_eq!(t.name, "premium");
        assert_eq!(t.outcomes, 20);
        assert_eq!(t.errors, 1);
        assert!(t.exhausted_at.is_none());
        assert!(!rep.exhausted());
        assert!(rep.check().is_ok());
        // 1 error in 20 at a 10% budget: half the budget spent.
        assert!((t.budget_spent - 0.5).abs() < 1e-12, "{}", t.budget_spent);
        // All outcomes in one 60 s fast window: burn = 0.05 / 0.10 = 0.5.
        assert!((t.max_fast_burn - 0.5).abs() < 1e-12, "{}", t.max_fast_burn);
    }

    #[test]
    fn exhausting_trace_fails_with_a_timeline() {
        // 10 outcomes, 3 errors against a 10% budget (allowed: 1).
        let mut evs: Vec<SpanEvent> =
            (0..7).map(|i| completed(i as f64 * 10.0, i, 0, 10.0)).collect();
        evs.push(dropped(71.0, 7, 0));
        evs.push(dropped(72.0, 8, 0));
        evs.push(completed(95.0, 9, 0, 400.0));
        let rep = report_from_trace(&evs, &classes(), SloOptions::default());
        let t = &rep.tenants[0];
        assert_eq!(t.errors, 3);
        // Budget is 1.0 error; the second error (t=72) exceeds it.
        assert_eq!(t.exhausted_at, Some(72.0));
        assert!(rep.exhausted());
        let err = rep.check().unwrap_err().to_string();
        assert!(err.contains("premium"), "{err}");
        assert!(err.contains("exhausted"), "{err}");
    }

    #[test]
    fn burn_rate_peaks_in_the_bad_window() {
        // Window [0,60): clean. Window [60,120): 2 of 4 outcomes err.
        let mut evs: Vec<SpanEvent> =
            (0..8).map(|i| completed(i as f64, i, 1, 10.0)).collect();
        evs.push(completed(61.0, 10, 1, 10.0));
        evs.push(completed(62.0, 11, 1, 10.0));
        evs.push(dropped(63.0, 12, 1));
        evs.push(dropped(64.0, 13, 1));
        let rep = report_from_trace(&evs, &classes(), SloOptions::default());
        let t = &rep.tenants[0];
        assert_eq!(t.name, "batch");
        // batch target 0.5 → err_frac 0.5; bad window rate 0.5 → burn 1.0.
        assert!((t.max_fast_burn - 1.0).abs() < 1e-12, "{}", t.max_fast_burn);
        // Slow window (300 s) pools everything: 2/12 / 0.5 = 1/3.
        assert!((t.max_slow_burn - 2.0 / 12.0 / 0.5).abs() < 1e-12);
    }

    #[test]
    fn untenanted_and_unknown_tenants_resolve_to_fallback_classes() {
        let evs = vec![completed(5.0, 1, NO_TENANT, 10.0), dropped(6.0, 2, 7)];
        let rep = report_from_trace(&evs, &classes(), SloOptions::default());
        let names: Vec<&str> = rep.tenants.iter().map(|t| t.name.as_str()).collect();
        assert_eq!(names, vec!["tenant-7", "all"]);
    }

    #[test]
    fn series_report_matches_hits_and_misses() {
        let mut sampler = crate::obs::FleetSampler::new(
            60.0,
            64,
            vec!["premium".into(), "batch".into()],
        );
        let cum = crate::obs::timeseries::TenantCum {
            slo_met: vec![9, 1],
            completed: vec![10, 4],
            dropped: vec![0, 2],
        };
        sampler.advance(60.0, crate::obs::timeseries::FleetGauges::default(), 0.0, &cum);
        let series = sampler.into_series();
        let rep = report_from_series(&series, &classes(), SloOptions::default());
        assert_eq!(rep.tenants.len(), 2);
        let premium = &rep.tenants[0];
        assert_eq!(premium.outcomes, 10);
        assert_eq!(premium.errors, 1);
        assert!(premium.exhausted_at.is_none());
        let batch = &rep.tenants[1];
        // batch: 4 completed (1 in SLO) + 2 dropped = 6 outcomes, 5 errors
        // against a 50% budget (3 allowed) → exhausted.
        assert_eq!(batch.outcomes, 6);
        assert_eq!(batch.errors, 5);
        assert!(batch.exhausted_at.is_some());
        assert!(rep.check().is_err());
        let doc = rep.to_json("test").to_json();
        assert!(doc.contains("\"schema\":\"eat-slo-report-v1\""), "{doc}");
        assert!(doc.contains("\"exhausted\":true"), "{doc}");
        let text = rep.render("test");
        assert!(text.contains("premium") && text.contains("batch"), "{text}");
    }
}
