// Violates `pragma`: the suppression below carries no justification, so
// it suppresses nothing and is itself a finding (plus the logging finding
// it failed to suppress).
pub fn report(total: usize) {
    // eat-lint: allow(logging)
    println!("total {total}");
}
