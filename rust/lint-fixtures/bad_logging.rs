// Violates `logging`: stdio macros outside obs/log.rs without a pragma.
// A comment saying println! and a string "eprintln!(no)" must NOT flag.
pub fn progress(done: usize, total: usize) {
    println!("processed {done}/{total}");
    let label = "println! in a string is fine";
    eprintln!("{label}");
}
