// Violates `determinism`: wall clocks and randomized-iteration
// containers in a deterministic-tier (sim/) module.
use std::collections::HashMap;
use std::time::Instant;

pub fn step(state: &mut HashMap<u64, f64>) -> f64 {
    let t0 = Instant::now();
    for (_, v) in state.iter_mut() {
        *v += 1.0;
    }
    t0.elapsed().as_secs_f64()
}
