// Violates `unwrap`: undocumented panics on a sim/ hot path. The
// `.lock().unwrap()` would be exempt (mutex-poisoning idiom); the plain
// unwrap/expect are not.
pub fn pick(xs: &[f64]) -> f64 {
    let first = xs.first().unwrap();
    let last = xs.last().expect("empty slice");
    first + last
}
