// Violates `rng`: ad-hoc stream-0 seeding in a deterministic-tier
// module. Substreams must be derived (Pcg64::new(seed, stream) / fork)
// so CRN-paired runs cannot collide on the same stream.
use crate::util::rng::Pcg64;

pub fn jitter(seed: u64) -> f64 {
    let mut rng = Pcg64::seeded(seed);
    rng.next_f64()
}
