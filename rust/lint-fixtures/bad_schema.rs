// Violates `schema`: an eat-*-vN wire name minted outside obs/schema.rs.
pub fn meta_line() -> String {
    format!("{{\"schema\":\"{}\"}}", "eat-bogus-v1")
}
