//! End-to-end benches, one per reproduced paper artifact: full episode
//! runs for the cheap policies, the motivating trace (Tables II–IV), and
//! the model probes behind Tables I/VI and Figs 6/7.

use eat::config::{ExecModelConfig, ExperimentConfig};
use eat::coordinator::run_episode;
use eat::policy::{GreedyPolicy, RandomPolicy};
use eat::sim::env::EdgeEnv;
use eat::sim::exec_model::ExecModel;
use eat::util::bench::Bencher;
use eat::util::rng::Pcg64;
use std::time::Duration;

fn main() {
    let mut b = Bencher::new(
        Duration::from_millis(200),
        Duration::from_secs(3),
        1_000_000,
    );

    // Table I / VI / Fig 6 / Fig 7 are all exec-model probes.
    let em = ExecModel::new(ExecModelConfig::default());
    b.bench("table1_probe_acceleration_row", || {
        let mut rng = Pcg64::seeded(1);
        (
            em.sample_exec(45, 1, &mut rng),
            em.sample_exec(45, 8, &mut rng),
        )
    });
    b.bench("fig6_probe_init_sample", || {
        let mut rng = Pcg64::seeded(2);
        em.sample_init(4, &mut rng)
    });

    // Tables IX-XI rows: one full evaluation episode per policy.
    for nodes in [4usize, 8, 12] {
        let cfg = ExperimentConfig::preset(nodes);
        b.bench(&format!("episode_greedy_n{nodes}"), || {
            let mut env = EdgeEnv::new(cfg.env.clone(), 3);
            let mut p = GreedyPolicy::new(cfg.env.clone());
            run_episode(&mut env, &mut p, None).completed_tasks
        });
    }
    let cfg = ExperimentConfig::preset_8node(0.1);
    b.bench("episode_random_n8", || {
        let mut env = EdgeEnv::new(cfg.env.clone(), 4);
        let mut p = RandomPolicy::new(cfg.env.clone(), 4);
        run_episode(&mut env, &mut p, None).completed_tasks
    });

    // Tables II-IV: the motivating 4-task trace.
    b.bench("motivation_trace_traditional", || {
        use eat::coordinator::traditional::run_traditional;
        use eat::sim::task::Workload;
        let mut cfg4 = ExperimentConfig::preset_4node(0.05).env;
        cfg4.num_models = 1;
        cfg4.tasks_per_episode = 4;
        cfg4.time_limit = 400.0;
        cfg4.step_limit = 400;
        let wl = Workload::fixed(&[(0.0, 2, 0), (10.0, 2, 0), (20.0, 4, 0), (30.0, 2, 0)]);
        let mut env = EdgeEnv::with_workload(cfg4, wl, Pcg64::seeded(5));
        run_traditional(&mut env).completed_tasks
    });

    println!("\n{}", b.summary());
}
