//! Workload-subsystem benches: 1M-task stream generation per arrival
//! process (scenario generation must stay off the hot-path budget — a
//! sweep regenerates workloads for every scenario × policy × episode),
//! plus histogram observation/percentile costs.
//!
//! Uses the in-repo bench harness (`util::bench`); criterion is not
//! available in the offline registry.

use std::time::Duration;

use eat::config::ExperimentConfig;
use eat::util::bench::{black_box, Bencher};
use eat::util::rng::Pcg64;
use eat::workload::{self, LatencyHistogram, TaskStream, WorkloadConfig};

const STREAM_TASKS: usize = 1_000_000;

fn main() {
    // Whole-stream iterations are ~10-100 ms each; trim warmup/budget so
    // the full suite stays under a minute.
    let mut b = Bencher::new(Duration::from_millis(50), Duration::from_millis(800), 1_000_000);
    let cfg = ExperimentConfig::preset_8node(0.1).env;

    for name in WorkloadConfig::scenario_names() {
        let wcfg = WorkloadConfig::preset(name, 0.1).unwrap();
        let res = b
            .bench(&format!("generate_1M_tasks_{name}"), || {
                let (mut ap, mix) = wcfg.build(&cfg);
                let mut rng = Pcg64::seeded(1);
                let w = workload::generate(ap.as_mut(), &mix, STREAM_TASKS, &mut rng);
                black_box(w.len())
            })
            .clone();
        println!(
            "       -> {:.1}M tasks/s",
            STREAM_TASKS as f64 * res.throughput_per_sec() / 1e6
        );
    }

    // Lazy stream pop (the path EdgeEnv drives every decision tick).
    let wcfg = WorkloadConfig::preset("bursty", 0.1).unwrap();
    b.bench("stream_pop_100k_bursty", || {
        let (ap, mix) = wcfg.build(&cfg);
        let mut stream = TaskStream::new(ap, mix, 100_000, Pcg64::seeded(2));
        let mut n = 0usize;
        while stream.pop_if_arrived(f64::INFINITY).is_some() {
            n += 1;
        }
        black_box(n)
    });

    // Histogram hot path: observe + percentile queries.
    b.bench("histogram_observe_100k", || {
        let mut h = LatencyHistogram::default_latency();
        for i in 0..100_000u32 {
            h.observe((i % 2000) as f64 * 0.37);
        }
        black_box(h.count())
    });
    let mut filled = LatencyHistogram::default_latency();
    for i in 0..100_000u32 {
        filled.observe((i % 2000) as f64 * 0.37);
    }
    b.bench("histogram_p99_query", || black_box(filled.p99()));

    println!("\n{}", b.summary());
}
