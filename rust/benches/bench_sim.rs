//! Simulator hot-path benches: state building, cluster selection, env
//! stepping, workload generation — the L3 code under every training and
//! evaluation loop.

use eat::config::ExperimentConfig;
use eat::sim::cluster::Cluster;
use eat::sim::env::{Action, EdgeEnv};
use eat::sim::task::{ModelType, Workload};
use eat::util::bench::Bencher;
use eat::util::rng::Pcg64;

fn main() {
    let mut b = Bencher::default();
    let cfg = ExperimentConfig::preset_8node(0.1);

    b.bench("workload_generate_32_tasks", || {
        let mut rng = Pcg64::seeded(1);
        Workload::generate(&cfg.env, &mut rng)
    });

    let env = EdgeEnv::new(cfg.env.clone(), 2);
    b.bench("env_state_build_8node", || env.state());

    let mut cluster = Cluster::new(8);
    // Populate some gangs for a realistic selection workload.
    let ids: Vec<usize> = (0..4).collect();
    cluster.dispatch(&ids, 1.0, ModelType(0), false, 0.0);
    cluster.advance(1.0, 1.0);
    b.bench("cluster_select_reuse_hit", || cluster.select(ModelType(0), 4));
    b.bench("cluster_select_fresh", || cluster.select(ModelType(2), 2));

    b.bench("env_step_noop", || {
        let mut env = EdgeEnv::new(cfg.env.clone(), 3);
        env.step(&Action::noop(cfg.env.queue_window))
    });

    b.bench("env_full_episode_scheduling", || {
        let mut env = EdgeEnv::new(cfg.env.clone(), 4);
        let mut scores = vec![-1.0f32; cfg.env.queue_window];
        scores[0] = 1.0;
        let action = Action {
            exec_gate: -1.0,
            steps_raw: 1.0,
            task_scores: scores,
        };
        loop {
            if env.step(&action).done {
                break;
            }
        }
        env.report().completed_tasks
    });

    b.bench("rng_fill_normal_1k", || {
        let mut rng = Pcg64::seeded(5);
        let mut buf = vec![0f32; 1024];
        rng.fill_normal_f32(&mut buf);
        buf[0]
    });

    println!("\n{}", b.summary());
}
