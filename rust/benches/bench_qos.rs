//! QoS-subsystem benches: deadline-aware priority-queue push/pop
//! throughput at a 1M-task backlog (the overload regime admission control
//! exists for), steady-state churn at a bounded depth, and the env-facing
//! `PendingQueue` rebuild cost at decision-cadence depths.
//!
//! Uses the in-repo bench harness (`util::bench`); criterion is not
//! available in the offline registry.

use std::time::Duration;

use eat::qos::{EdfWfqQueue, PendingQueue, TenantRegistry, TenantsConfig};
use eat::sim::task::{ModelType, Task};
use eat::util::bench::{black_box, Bencher};
use eat::util::rng::Pcg64;

fn task(id: u64, tenant: Option<u32>, deadline: f64) -> Task {
    Task {
        id,
        prompt_id: id,
        patches: 2,
        model: ModelType(0),
        arrival: 0.0,
        q_min: None,
        tenant,
        deadline: Some(deadline),
    }
}

const BULK: usize = 1_000_000;

fn main() {
    let mut b = Bencher::new(Duration::from_millis(10), Duration::from_millis(600), 1_000_000);

    // Bulk: push 1M tasks across three weighted tiers, then drain them in
    // SWRR + EDF order. One iteration is the whole 2M-op cycle.
    let res = b
        .bench("qos_queue_push_pop_1M_tasks", || {
            let mut q = EdfWfqQueue::new(vec![6.0, 3.0, 1.0]);
            let mut rng = Pcg64::seeded(7);
            for id in 0..BULK as u64 {
                let tier = (id % 3) as usize;
                q.push(tier, task(id, Some(tier as u32), rng.uniform(0.0, 1e6)));
            }
            let mut n = 0usize;
            while q.pop().is_some() {
                n += 1;
            }
            black_box(n)
        })
        .clone();
    println!(
        "       -> {:.2}M push+pop pairs/s",
        BULK as f64 * res.throughput_per_sec() / 1e6
    );

    // Steady state: one push + one pop per iteration at a 4096-deep
    // backlog (the per-decision cost an overloaded env would pay).
    let mut steady = EdfWfqQueue::new(vec![6.0, 3.0, 1.0]);
    let mut rng = Pcg64::seeded(8);
    for id in 0..4096u64 {
        steady.push((id % 3) as usize, task(id, Some((id % 3) as u32), rng.uniform(0.0, 1e6)));
    }
    let mut next_id = 4096u64;
    b.bench("qos_queue_push_pop_at_depth_4096", || {
        let tier = (next_id % 3) as usize;
        steady.push(tier, task(next_id, Some(tier as u32), rng.uniform(0.0, 1e6)));
        next_id += 1;
        black_box(steady.pop().is_some())
    });

    // Env-facing adapter: push + remove with the materialised view rebuilt
    // each mutation, at a decision-cadence depth.
    let registry = TenantRegistry::new(&TenantsConfig::three_tier(0.3));
    let mut pending = PendingQueue::qos(registry);
    let mut rng2 = Pcg64::seeded(9);
    for id in 0..64u64 {
        pending.push(task(id, Some((id % 3) as u32), rng2.uniform(0.0, 1e4)));
    }
    let mut pid = 64u64;
    b.bench("pending_queue_churn_at_depth_64", || {
        pending.push(task(pid, Some((pid % 3) as u32), rng2.uniform(0.0, 1e4)));
        pid += 1;
        black_box(pending.remove(0).is_some())
    });

    println!("\n{}", b.summary());
}
