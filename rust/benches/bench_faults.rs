//! Fault-subsystem benches: stepping the health process over a 10k-server
//! fleet (the per-tick cost the simulator pays for churn modelling), the
//! same fleet under heavy straggler traffic, and a full churn episode
//! through `EdgeEnv` to keep the end-to-end overhead visible.
//!
//! Uses the in-repo bench harness (`util::bench`); criterion is not
//! available in the offline registry.

use std::time::Duration;

use eat::config::ExperimentConfig;
use eat::faults::{FaultModel, FaultsConfig};
use eat::sim::env::{Action, EdgeEnv};
use eat::util::bench::{black_box, Bencher};
use eat::util::rng::Pcg64;

const FLEET: usize = 10_000;
const TICKS: usize = 100;

fn main() {
    let mut b = Bencher::new(Duration::from_millis(50), Duration::from_millis(800), 1_000_000);

    // Pure churn: up/down Markov transitions + zone shocks across 10k
    // servers, 100 ticks per iteration.
    let churn = FaultsConfig {
        mtbf: 600.0,
        mttr: 45.0,
        zones: 16,
        zone_shock_rate: 0.01,
        straggler_rate: 0.0,
        ..FaultsConfig::default()
    };
    let res = b
        .bench("fault_model_churn_10k_servers_100_ticks", || {
            let mut m = FaultModel::stochastic(churn.clone(), FLEET, Pcg64::seeded(1));
            let mut events = 0usize;
            for t in 0..TICKS {
                events += m.step(t as f64, 1.0).len();
            }
            black_box(events)
        })
        .clone();
    println!(
        "       -> {:.1}M server-ticks/s",
        (FLEET * TICKS) as f64 * res.throughput_per_sec() / 1e6
    );

    // Straggler-heavy dynamics: slowdown bouts starting/ending everywhere.
    let slow = FaultsConfig {
        mtbf: 0.0,
        zone_shock_rate: 0.0,
        straggler_rate: 0.05,
        straggler_mean_duration: 10.0,
        ..FaultsConfig::default()
    };
    b.bench("fault_model_stragglers_10k_servers_100_ticks", || {
        let mut m = FaultModel::stochastic(slow.clone(), FLEET, Pcg64::seeded(2));
        let mut events = 0usize;
        for t in 0..TICKS {
            events += m.step(t as f64, 1.0).len();
        }
        black_box(events)
    });

    // End to end: a full churn episode through the env (kills, retries,
    // speculation, deferred accounting) vs the fault-free baseline.
    let mut cfg = ExperimentConfig::preset_8node(0.1).env;
    cfg.tasks_per_episode = 48;
    cfg.patch_choices = vec![1, 2];
    cfg.patch_weights = vec![1.0, 1.0];
    let run_episode = |cfg: &eat::config::EnvConfig| {
        let mut env = EdgeEnv::new(cfg.clone(), 7);
        let l = cfg.queue_window;
        let mut scores = vec![-1.0f32; l];
        scores[0] = 1.0;
        let action = Action {
            exec_gate: -1.0,
            steps_raw: 0.4,
            task_scores: scores,
        };
        for _ in 0..=cfg.step_limit {
            if env.step(&action).done {
                break;
            }
        }
        env.report().completed_tasks
    };
    let baseline = cfg.clone();
    b.bench("episode_8node_fault_free", || black_box(run_episode(&baseline)));
    let mut churny = cfg.clone();
    churny.faults = Some(FaultsConfig {
        mtbf: 200.0,
        mttr: 30.0,
        ..FaultsConfig::default()
    });
    b.bench("episode_8node_under_churn", || black_box(run_episode(&churny)));

    println!("\n{}", b.summary());
}
