//! Per-decision latency benches (Table XII's microbenchmark): how long
//! each scheduling algorithm takes to produce one composite action.

use eat::config::{Algorithm, ExperimentConfig};
use eat::policy::{GreedyPolicy, Policy, RandomPolicy};
use eat::rl::SacDriver;
use eat::runtime::Runtime;
use eat::sim::env::{Action, EdgeEnv};
use eat::util::bench::Bencher;

fn main() {
    let mut b = Bencher::default();
    let cfg = ExperimentConfig::preset_4node(0.05);
    // Environment with a populated queue for a realistic decision.
    let mut env = EdgeEnv::new(cfg.env.clone(), 7);
    while env.queue().len() < 3 {
        env.step(&Action::noop(cfg.env.queue_window));
    }

    let mut random = RandomPolicy::new(cfg.env.clone(), 1);
    b.bench("decide_random", || random.decide(&env).unwrap());

    let mut greedy = GreedyPolicy::new(cfg.env.clone());
    b.bench("decide_greedy_enumerate_all", || greedy.decide(&env).unwrap());

    // RL decision latency (needs artifacts; skipped otherwise).
    match Runtime::new("artifacts") {
        Ok(rt) => {
            for alg in [
                Algorithm::Eat,
                Algorithm::EatA,
                Algorithm::EatD,
                Algorithm::EatDa,
            ] {
                let mut c = cfg.clone();
                c.algorithm = alg;
                if let Ok(mut driver) = SacDriver::new(&rt, &c) {
                    let state = env.state();
                    b.bench(&format!("decide_{}", alg.name().to_lowercase()), || {
                        driver.act(&state, true).unwrap()
                    });
                }
            }
        }
        Err(e) => eprintln!("skipping RL decision benches: {e}"),
    }

    println!("\n{}", b.summary());
}
