//! Event-core benches: the calendar queue, the residency-indexed selector
//! vs the legacy full-fleet scan, the busy-set advance, and the evented
//! env step — the hot paths behind `eat bench`'s BENCH_sim.json numbers.

use eat::config::ExperimentConfig;
use eat::sim::cluster::Cluster;
use eat::sim::env::{Action, EdgeEnv};
use eat::sim::events::EventQueue;
use eat::sim::task::ModelType;
use eat::util::bench::Bencher;

/// A 10k-server cluster with a quarter of the fleet busy and a spread of
/// warm idle gangs — the selection regime the index is built for.
fn populated_cluster(n: usize) -> Cluster {
    let mut cluster = Cluster::new(n);
    let mut id = 0usize;
    let mut model = 0u32;
    while id + 4 <= n / 2 {
        let gang: Vec<usize> = (id..id + 4).collect();
        cluster.dispatch(&gang, 1.0, ModelType(model % 5), false, 0.0);
        model += 1;
        id += 4;
    }
    // Half of the dispatched gangs finish and stay warm-idle; the rest
    // keep running.
    cluster.advance(1.0, 1.0);
    let mut running = 0usize;
    while running + 4 <= n / 4 {
        let gang: Vec<usize> = (running..running + 4).collect();
        cluster.dispatch(&gang, 50.0, ModelType(7), false, 1.0);
        running += 4;
    }
    cluster
}

fn main() {
    let mut b = Bencher::default();

    b.bench("event_queue_push_pop_1k", || {
        let mut q = EventQueue::new();
        for i in 0..1000u64 {
            q.push((i % 97) as f64, i);
        }
        let mut out = Vec::new();
        let mut total = 0usize;
        while !q.is_empty() {
            total += q.pop_due_into(32.0, &mut out);
            if out.is_empty() {
                break;
            }
        }
        total
    });

    let cluster = populated_cluster(10_000);
    b.bench("select_indexed_reuse_10k", || cluster.select(ModelType(1), 4));
    b.bench("select_indexed_fresh_10k", || cluster.select(ModelType(9), 4));
    b.bench("select_scan_reuse_10k", || {
        cluster.select_filtered_scan(ModelType(1), 4, false)
    });
    b.bench("select_scan_fresh_10k", || {
        cluster.select_filtered_scan(ModelType(9), 4, false)
    });

    b.bench("advance_busy_set_10k", || {
        let mut c = populated_cluster(10_000);
        let mut finished = Vec::new();
        for t in 0..50 {
            c.advance_into(1.0, t as f64, &mut finished);
        }
        c.idle_count()
    });
    b.bench("advance_full_scan_10k", || {
        let mut c = populated_cluster(10_000);
        let mut finished = Vec::new();
        for t in 0..50 {
            c.advance_scan_into(1.0, t as f64, &mut finished);
        }
        c.idle_count()
    });

    let mut cfg = ExperimentConfig::preset(8).env;
    cfg.num_servers = 1_000;
    cfg.arrival_rate = 12.5;
    cfg.tasks_per_episode = 500;
    b.bench("env_step_event_core_1k_servers", || {
        let mut env = EdgeEnv::new(cfg.clone(), 3);
        let noop = Action::noop(cfg.queue_window);
        for _ in 0..20 {
            while let Some(idx) = env.first_feasible() {
                if env.schedule_task_at(idx, 20).is_none() {
                    break;
                }
            }
            if env.step(&noop).done {
                break;
            }
        }
        env.queue().len()
    });
    b.bench("env_step_tick_core_1k_servers", || {
        let mut env = EdgeEnv::new(cfg.clone(), 3);
        env.set_legacy_scan(true);
        let noop = Action::noop(cfg.queue_window);
        for _ in 0..20 {
            while let Some(idx) = env.first_feasible() {
                if env.schedule_task_at(idx, 20).is_none() {
                    break;
                }
            }
            if env.step(&noop).done {
                break;
            }
        }
        env.queue().len()
    });

    println!("\n{}", b.summary());
}
