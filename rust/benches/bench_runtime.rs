//! PJRT runtime benches: HLO execution cost for the act and train
//! entrypoints plus input-literal conversion overhead — the L3↔XLA
//! boundary that dominates training throughput (§Perf).

use eat::config::ExperimentConfig;
use eat::rl::SacDriver;
use eat::runtime::Runtime;
use eat::util::bench::Bencher;
use eat::util::rng::Pcg64;

fn main() {
    let Ok(rt) = Runtime::new("artifacts") else {
        eprintln!("no artifacts; run `make artifacts` first");
        return;
    };
    let mut b = Bencher::default();
    let cfg = ExperimentConfig::preset_8node(0.1);

    // act: single-state forward through attention + 10-step diffusion.
    let exe = rt.load("eat_n8l8_act").unwrap();
    let p = rt.manifest.param("eat_n8l8").unwrap().clone();
    let actor = rt.manifest.load_init("eat_n8l8", "actor").unwrap();
    let state = vec![0.25f32; p.state_dim];
    let chain = vec![0.1f32; p.chain_steps * p.action_dim];
    let expl = vec![0.0f32; p.action_dim];
    b.bench("pjrt_act_eat_n8l8", || {
        exe.run(&[&actor, &state, &chain, &expl]).unwrap()
    });

    // act for the cheapest ablation (plain MLP SAC) as the floor.
    if rt.has_entry("eat_da_n8l8_act") {
        let exe_da = rt.load("eat_da_n8l8_act").unwrap();
        let actor_da = rt.manifest.load_init("eat_da_n8l8", "actor").unwrap();
        b.bench("pjrt_act_eat_da_n8l8", || {
            exe_da.run(&[&actor_da, &state, &expl]).unwrap()
        });
    }

    // §Perf before/after: full-upload act vs device-resident actor params.
    let mut driver = SacDriver::new(&rt, &cfg).unwrap();
    {
        let state_v = vec![0.25f32; p.state_dim];
        b.bench("act_before_upload_all_params", || {
            driver.act_upload_all(&state_v).unwrap()
        });
        b.bench("act_after_device_resident_params", || {
            driver.act(&state_v, true).unwrap()
        });
    }
    let mut rng = Pcg64::seeded(1);
    let a_dim = cfg.env.action_len();
    for _ in 0..rt.manifest.batch_size.max(cfg.train.batch_size) {
        let mut s = vec![0f32; p.state_dim];
        let mut a = vec![0f32; a_dim];
        rng.fill_uniform_f32(&mut s);
        rng.fill_normal_f32(&mut a);
        driver.replay.push(&s, &a, rng.next_f32(), &s, false);
    }
    let batch = rt.manifest.batch_size;
    b.bench("pjrt_train_step_eat_n8l8", || driver.update(batch).unwrap());

    println!("\n{}", b.summary());
}
