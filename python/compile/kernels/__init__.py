"""Layer-1 Pallas kernels (interpret mode) and their pure-jnp oracles.

The scheduler's compute hot spots are (a) the attention feature extraction
over the state sequence (paper Eq. 9) and (b) the diffusion denoiser MLP
applied T times per action (paper Eqs. 10-12). Both are implemented as
fused Pallas kernels so the whole per-decision compute is two kernel
launches per denoise step; `ref.py` holds the jnp reference implementations
that pytest checks them against.
"""

from compile.kernels.attention import attention_feature
from compile.kernels.denoise import denoiser_mlp

__all__ = ["attention_feature", "denoiser_mlp"]
