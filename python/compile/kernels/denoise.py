"""Fused diffusion-denoiser MLP (Pallas, L1).

The reverse-diffusion policy calls eps_theta(x_i, i, f_s) T times per
action (Algorithm 1 lines 6-9). Each call is a 2-hidden-layer 256x256 MLP
with Mish activations and a linear output (Table VII). This kernel fuses
the three matmuls + activations into one launch; the whole parameter set
(~ (C+256)*256 + 256*256 + 256*A floats ~= 0.6 MiB for C~70) stays
VMEM-resident across the fused computation, and the 256x256 inner matmul
maps onto two MXU 128x128 tiles per operand pane (DESIGN.md §Perf).

interpret=True for CPU-PJRT executability (see attention.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mish(x):
    # mish(x) = x * tanh(softplus(x)); softplus in float32 is stable for
    # |x| < 30ish, clamp to avoid overflow in exp.
    sp = jnp.logaddexp(x, 0.0)
    return x * jnp.tanh(sp)


def _denoiser_kernel(z_ref, w1_ref, b1_ref, w2_ref, b2_ref, w3_ref, b3_ref, o_ref):
    """z: (B, C) conditioned input; w1: (C, H); w2: (H, H); w3: (H, A)."""
    z = z_ref[...]
    h1 = _mish(z @ w1_ref[...] + b1_ref[...])
    h2 = _mish(h1 @ w2_ref[...] + b2_ref[...])
    o_ref[...] = h2 @ w3_ref[...] + b3_ref[...]


def _denoiser_pallas(z, w1, b1, w2, b2, w3, b3):
    b, _ = z.shape
    a = w3.shape[1]
    return pl.pallas_call(
        _denoiser_kernel,
        out_shape=jax.ShapeDtypeStruct((b, a), z.dtype),
        interpret=True,
    )(z, w1, b1, w2, b2, w3, b3)


def _denoiser_ref(z, w1, b1, w2, b2, w3, b3):
    h1 = _mish(z @ w1 + b1)
    h2 = _mish(h1 @ w2 + b2)
    return h2 @ w3 + b3


@jax.custom_vjp
def denoiser_mlp(z, w1, b1, w2, b2, w3, b3):
    """eps = MLP(z): (B, C) -> (B, A), Mish-Mish-linear, fused.

    Used both as the diffusion eps-network and (with different shapes) as
    the plain MLP actor/critic trunk, so one kernel covers every network
    in Table VII.

    Forward runs the fused Pallas kernel; the backward pass is the VJP of
    the (bit-identical) reference computation — interpret-mode pallas_call
    has no reverse-mode rule, and on real hardware one would hand a fused
    backward kernel to this same custom_vjp hook.
    """
    return _denoiser_pallas(z, w1, b1, w2, b2, w3, b3)


def _denoiser_fwd(z, w1, b1, w2, b2, w3, b3):
    out = _denoiser_pallas(z, w1, b1, w2, b2, w3, b3)
    return out, (z, w1, b1, w2, b2, w3, b3)


def _denoiser_bwd(res, g):
    _, vjp = jax.vjp(_denoiser_ref, *res)
    return vjp(g)


denoiser_mlp.defvjp(_denoiser_fwd, _denoiser_bwd)
