"""Pure-jnp oracles for the Pallas kernels (pytest compares against these).

Keep these boring and obviously correct: straight-line jnp with no Pallas,
no fusion tricks. They double as the reference used by hypothesis sweeps in
python/tests/test_kernels.py.
"""

import math

import jax.numpy as jnp


def mish(x):
    return x * jnp.tanh(jnp.logaddexp(x, 0.0))


def attention_feature_ref(x, we, wq, wk, wv, wo):
    """Reference for kernels.attention.attention_feature."""
    h = x @ we
    q = h @ wq
    k = h @ wk
    v = h @ wv
    scores = (q @ k.T) / math.sqrt(q.shape[-1])
    attn = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    attn = attn / jnp.sum(attn, axis=-1, keepdims=True)
    return ((attn @ v) @ wo)[:, 0]


def denoiser_mlp_ref(z, w1, b1, w2, b2, w3, b3):
    """Reference for kernels.denoise.denoiser_mlp."""
    h1 = mish(z @ w1 + b1)
    h2 = mish(h1 @ w2 + b2)
    return h2 @ w3 + b3
