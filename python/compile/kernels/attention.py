"""Fused single-head self-attention feature extractor (Pallas, L1).

The EAT state (Eq. 6) is a 3x(|E|+l) matrix; each column (one server or one
queue slot) is a token. The kernel embeds each 3-vector token to d_model,
runs scaled-dot-product self-attention (Eq. 9) over the N tokens, and
projects each token back to a scalar, producing the feature vector
f_s in R^N that conditions the diffusion policy (Table VII: attention layer
output units = |E| + l).

Everything (embed -> QKV -> softmax(QK^T/sqrt(d))V -> scalar head) is fused
in one Pallas kernel: for the paper's sizes (N <= 20, d = 16) all operands
fit VMEM comfortably (a few KiB per sample), so the whole computation is a
single block with no HBM round-trips between the five matmuls. The batched
variant keeps the batch dimension inside the same block — at B = 128,
N = 20, d = 16 the live set is ~0.7 MiB, still far under a TPU core's
~16 MiB VMEM (DESIGN.md §Perf has the footprint table).

interpret=True: the CPU PJRT plugin cannot run Mosaic custom-calls; the
interpret path lowers to plain HLO, which is what the AOT bridge ships to
the rust runtime. See DESIGN.md §Hardware-Adaptation.
"""

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attention_kernel_batched(x_ref, we_ref, wq_ref, wk_ref, wv_ref, wo_ref, o_ref):
    """x: (B, N, 3) tokens; we: (3, d); wq/wk/wv: (d, d); wo: (d, 1)."""
    x = x_ref[...]
    h = jnp.einsum("bnc,cd->bnd", x, we_ref[...])
    q = jnp.einsum("bnd,de->bne", h, wq_ref[...])
    k = jnp.einsum("bnd,de->bne", h, wk_ref[...])
    v = jnp.einsum("bnd,de->bne", h, wv_ref[...])
    d_k = q.shape[-1]
    scores = jnp.einsum("bnd,bmd->bnm", q, k) / math.sqrt(d_k)
    # Numerically stable softmax, single pass over VMEM-resident scores.
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    attn = e / jnp.sum(e, axis=-1, keepdims=True)
    ctx = jnp.einsum("bnm,bmd->bnd", attn, v)
    o_ref[...] = jnp.einsum("bnd,do->bno", ctx, wo_ref[...])[:, :, 0]


def _attention_pallas(x, we, wq, wk, wv, wo):
    b, n, _ = x.shape
    return pl.pallas_call(
        _attention_kernel_batched,
        out_shape=jax.ShapeDtypeStruct((b, n), x.dtype),
        interpret=True,
    )(x, we, wq, wk, wv, wo)


def _attention_ref(x, we, wq, wk, wv, wo):
    h = jnp.einsum("bnc,cd->bnd", x, we)
    q = jnp.einsum("bnd,de->bne", h, wq)
    k = jnp.einsum("bnd,de->bne", h, wk)
    v = jnp.einsum("bnd,de->bne", h, wv)
    scores = jnp.einsum("bnd,bmd->bnm", q, k) / math.sqrt(q.shape[-1])
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    attn = e / jnp.sum(e, axis=-1, keepdims=True)
    ctx = jnp.einsum("bnm,bmd->bnd", attn, v)
    return jnp.einsum("bnd,do->bno", ctx, wo)[:, :, 0]


@jax.custom_vjp
def attention_feature_batched(x, we, wq, wk, wv, wo):
    """f_s = fused-attention(x) for a batch of state matrices.

    Args:
      x: (B, N, 3) state tokens (columns of the Eq. 6 matrix, transposed).
      we: (3, d) embedding; wq/wk/wv: (d, d); wo: (d, 1) scalar head.

    Returns:
      (B, N) feature vectors f_s.

    Forward = fused Pallas kernel; backward = VJP of the bit-identical
    reference (interpret-mode pallas_call has no reverse-mode rule; a real
    TPU build would register a fused backward kernel here instead).
    """
    return _attention_pallas(x, we, wq, wk, wv, wo)


def _attention_fwd(x, we, wq, wk, wv, wo):
    out = _attention_pallas(x, we, wq, wk, wv, wo)
    return out, (x, we, wq, wk, wv, wo)


def _attention_bwd(res, g):
    _, vjp = jax.vjp(_attention_ref, *res)
    return vjp(g)


attention_feature_batched.defvjp(_attention_fwd, _attention_bwd)


@functools.partial(jax.jit)
def attention_feature(x, we, wq, wk, wv, wo):
    """Single-sample convenience wrapper: (N, 3) -> (N,)."""
    return attention_feature_batched(x[None, ...], we, wq, wk, wv, wo)[0]
