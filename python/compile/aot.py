"""AOT bridge: lower every (algorithm x topology) act/train function to HLO
text, dump freshly-initialised parameter vectors, and write the manifest the
rust runtime consumes.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the published `xla` crate) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage (from python/):
    python -m compile.aot --out-dir ../artifacts            # full grid
    python -m compile.aot --out-dir ../artifacts --quick    # n8l8, eat+ppo
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model

# Topologies matching the paper's 4/8/12-node clusters (rust
# config::ExperimentConfig presets use the same queue windows).
TOPOLOGIES = {
    "n4l6": (4, 6),
    "n8l8": (8, 8),
    "n12l8": (12, 8),
}
SAC_ALGS = ["eat", "eat_a", "eat_d", "eat_da"]
ALL_ALGS = SAC_ALGS + ["ppo"]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_entry(name, arr_or_shape):
    shape = list(arr_or_shape.shape) if hasattr(arr_or_shape, "shape") else list(arr_or_shape)
    return {"name": name, "shape": shape}


def _write(path, text):
    with open(path, "w") as f:
        f.write(text)


def _dump_f32(path, arr):
    np.asarray(arr, dtype="<f4").tofile(path)


def lower_sac(alg: str, topo: str, out_dir: str, batch: int, denoise: int, manifest):
    servers, window = TOPOLOGIES[topo]
    spec = model.make_spec(
        alg, servers, window, denoise_steps=denoise, batch_size=batch
    )
    built = model.build_sac(spec)
    P = built["actor_flat0"].shape[0]
    C = built["critic1_flat0"].shape[0]
    A = spec.action_dim
    S = spec.state_dim
    T1 = spec.denoise_steps + 1 if spec.use_diffusion else 0
    f32 = jnp.float32
    sds = jax.ShapeDtypeStruct

    key = f"{alg}_{topo}"
    use_diffusion = T1 > 0  # EAT-D / EAT-DA lower without chain inputs

    # --- act ---------------------------------------------------------------
    act_inputs = [
        ("actor", (P,)),
        ("state", (S,)),
    ]
    if use_diffusion:
        act_inputs.append(("chain_noise", (T1, A)))
    act_inputs.append(("expl_noise", (A,)))
    lowered = jax.jit(built["act"]).lower(*[sds(s, f32) for _, s in act_inputs])
    act_file = f"{key}_act.hlo.txt"
    _write(os.path.join(out_dir, act_file), to_hlo_text(lowered))
    manifest["entries"][f"{key}_act"] = {
        "file": act_file,
        "inputs": [_spec_entry(n, s) for n, s in act_inputs],
        "outputs": [
            _spec_entry("action", (A,)),
            _spec_entry("mean", (A,)),
            _spec_entry("log_sigma", (A,)),
        ],
    }

    # --- train -------------------------------------------------------------
    B = spec.batch_size
    train_inputs = [
        ("actor", (P,)),
        ("critic1", (C,)),
        ("critic2", (C,)),
        ("critic1_target", (C,)),
        ("critic2_target", (C,)),
        ("m_actor", (P,)),
        ("v_actor", (P,)),
        ("m_critic1", (C,)),
        ("v_critic1", (C,)),
        ("m_critic2", (C,)),
        ("v_critic2", (C,)),
        ("t", ()),
        ("s", (B, S)),
        ("a", (B, A)),
        ("r", (B,)),
        ("s2", (B, S)),
        ("done", (B,)),
    ]
    if use_diffusion:
        train_inputs.append(("chain_s", (B, T1, A)))
        train_inputs.append(("chain_s2", (B, T1, A)))
    train_inputs.append(("expl_s", (B, A)))
    train_inputs.append(("expl_s2", (B, A)))
    lowered = jax.jit(built["train"]).lower(*[sds(s, f32) for _, s in train_inputs])
    train_file = f"{key}_train.hlo.txt"
    _write(os.path.join(out_dir, train_file), to_hlo_text(lowered))
    manifest["entries"][f"{key}_train"] = {
        "file": train_file,
        "inputs": [_spec_entry(n, s) for n, s in train_inputs],
        "outputs": [
            _spec_entry(n, s)
            for n, s in [
                ("actor", (P,)),
                ("critic1", (C,)),
                ("critic2", (C,)),
                ("critic1_target", (C,)),
                ("critic2_target", (C,)),
                ("m_actor", (P,)),
                ("v_actor", (P,)),
                ("m_critic1", (C,)),
                ("v_critic1", (C,)),
                ("m_critic2", (C,)),
                ("v_critic2", (C,)),
                ("t", ()),
                ("actor_loss", ()),
                ("critic_loss", ()),
                ("mean_q", ()),
                ("entropy", ()),
            ]
        ],
    }

    # --- initial parameters --------------------------------------------------
    init_files = {}
    for net, arr in [
        ("actor", built["actor_flat0"]),
        ("critic1", built["critic1_flat0"]),
        ("critic2", built["critic2_flat0"]),
    ]:
        fname = f"{key}_init_{net}.f32"
        _dump_f32(os.path.join(out_dir, fname), arr)
        init_files[net] = fname
    manifest["params"][key] = {
        "actor_len": int(P),
        "critic_len": int(C),
        "action_dim": int(A),
        "state_dim": int(S),
        "chain_steps": int(T1),
        "batch_size": int(B),
        "init_files": init_files,
    }


def lower_ppo(topo: str, out_dir: str, batch: int, manifest):
    servers, window = TOPOLOGIES[topo]
    spec = model.make_spec("ppo", servers, window, batch_size=batch)
    built = model.build_ppo(spec)
    P = built["actor_flat0"].shape[0]
    C = built["critic_flat0"].shape[0]
    A = spec.action_dim
    S = spec.state_dim
    f32 = jnp.float32
    sds = jax.ShapeDtypeStruct
    key = f"ppo_{topo}"

    act_inputs = [
        ("actor", (P,)),
        ("critic", (C,)),
        ("state", (S,)),
        ("expl_noise", (A,)),
    ]
    lowered = jax.jit(built["act"]).lower(*[sds(s, f32) for _, s in act_inputs])
    act_file = f"{key}_act.hlo.txt"
    _write(os.path.join(out_dir, act_file), to_hlo_text(lowered))
    manifest["entries"][f"{key}_act"] = {
        "file": act_file,
        "inputs": [_spec_entry(n, s) for n, s in act_inputs],
        "outputs": [
            _spec_entry("action", (A,)),
            _spec_entry("logp", ()),
            _spec_entry("value", ()),
        ],
    }

    B = spec.batch_size
    train_inputs = [
        ("actor", (P,)),
        ("critic", (C,)),
        ("m_actor", (P,)),
        ("v_actor", (P,)),
        ("m_critic", (C,)),
        ("v_critic", (C,)),
        ("t", ()),
        ("s", (B, S)),
        ("a", (B, A)),
        ("old_logp", (B,)),
        ("adv", (B,)),
        ("ret", (B,)),
    ]
    lowered = jax.jit(built["train"]).lower(*[sds(s, f32) for _, s in train_inputs])
    train_file = f"{key}_train.hlo.txt"
    _write(os.path.join(out_dir, train_file), to_hlo_text(lowered))
    manifest["entries"][f"{key}_train"] = {
        "file": train_file,
        "inputs": [_spec_entry(n, s) for n, s in train_inputs],
        "outputs": [
            _spec_entry(n, s)
            for n, s in [
                ("actor", (P,)),
                ("critic", (C,)),
                ("m_actor", (P,)),
                ("v_actor", (P,)),
                ("m_critic", (C,)),
                ("v_critic", (C,)),
                ("t", ()),
                ("pi_loss", ()),
                ("v_loss", ()),
                ("entropy", ()),
                ("approx_kl", ()),
            ]
        ],
    }

    init_files = {}
    for net, arr in [("actor", built["actor_flat0"]), ("critic", built["critic_flat0"])]:
        fname = f"{key}_init_{net}.f32"
        _dump_f32(os.path.join(out_dir, fname), arr)
        init_files[net] = fname
    manifest["params"][key] = {
        "actor_len": int(P),
        "critic_len": int(C),
        "action_dim": int(A),
        "state_dim": int(S),
        "chain_steps": 0,
        "batch_size": int(B),
        "init_files": init_files,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--topos", nargs="*", default=list(TOPOLOGIES))
    ap.add_argument("--algs", nargs="*", default=ALL_ALGS)
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--denoise", type=int, default=10)
    ap.add_argument("--quick", action="store_true", help="n8l8, eat+ppo only")
    args = ap.parse_args()
    if args.quick:
        args.topos = ["n8l8"]
        args.algs = ["eat", "ppo"]
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {
        "version": 1,
        "batch_size": args.batch,
        "denoise_steps": args.denoise,
        "entries": {},
        "params": {},
    }
    t_start = time.time()
    for topo in args.topos:
        for alg in args.algs:
            t0 = time.time()
            if alg == "ppo":
                lower_ppo(topo, args.out_dir, args.batch, manifest)
            else:
                lower_sac(alg, topo, args.out_dir, args.batch, args.denoise, manifest)
            print(f"lowered {alg}_{topo} in {time.time() - t0:.1f}s", flush=True)
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(
        f"wrote {len(manifest['entries'])} artifacts + manifest to "
        f"{args.out_dir} in {time.time() - t_start:.1f}s"
    )


if __name__ == "__main__":
    main()
