"""Layer-2 JAX model: the EAT policy/critic networks and whole train steps.

Implements, per the paper:
  - attention feature extraction over the state sequence (Eq. 9, via the
    L1 Pallas kernel `kernels.attention`),
  - the diffusion-based policy (Eqs. 10-13, Fig. 3): T reverse-diffusion
    steps through the fused denoiser-MLP kernel, a tanh-bounded action
    mean, and a variance head producing an exploration Gaussian,
  - SAC training (Eqs. 14-22): double critics, target networks, entropy
    regularised actor objective, in-graph Adam (Table VIII) — the whole
    update is ONE jitted function lowered to ONE HLO module,
  - the PPO baseline (clip objective; GAE advantages computed by the rust
    driver and passed in),
  - the ablations: EAT-A (no attention), EAT-D (no diffusion), EAT-DA
    (neither) — selected via `use_attention` / `use_diffusion`.

Everything stochastic (diffusion chain noise, exploration noise) enters as
explicit tensor inputs so the lowered HLO is pure; the rust coordinator's
PCG64 supplies the noise at runtime.

Parameters cross the AOT boundary as flat f32 vectors (ravel_pytree); the
unflattener is baked into the lowered module, and `aot.py` records each
network's length plus freshly-initialised parameter dumps in the manifest.
"""

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from compile.kernels.attention import attention_feature_batched
from compile.kernels.denoise import denoiser_mlp

HIDDEN = 256          # FC width (Table VII)
D_MODEL = 16          # attention embed dim
TIME_DIM = 16         # diffusion timestep embedding (Table VII)
LOG_SIG_MIN = -5.0
LOG_SIG_MAX = 2.0


@dataclasses.dataclass(frozen=True)
class AlgoSpec:
    """Static architecture description for one algorithm x topology."""

    name: str             # eat | eat_a | eat_d | eat_da | ppo
    num_servers: int      # |E|
    queue_window: int     # l
    denoise_steps: int    # T
    batch_size: int       # B
    gamma: float
    entropy_alpha: float
    soft_tau: float
    lr_actor: float
    lr_critic: float
    weight_decay: float
    ppo_clip: float = 0.2
    ppo_value_coef: float = 0.5
    ppo_entropy_coef: float = 0.01

    @property
    def n_cols(self):  # N = |E| + l
        return self.num_servers + self.queue_window

    @property
    def state_dim(self):  # S = 3N
        return 3 * self.n_cols

    @property
    def action_dim(self):  # A = [a_c, a_s, a_k1..a_kl]
        return 2 + self.queue_window

    @property
    def use_attention(self):
        return self.name in ("eat", "eat_d")

    @property
    def use_diffusion(self):
        return self.name in ("eat", "eat_a")

    @property
    def feature_dim(self):
        # Attention path emits f_s in R^N (Table VII); MLP path consumes
        # the flat state directly.
        return self.n_cols if self.use_attention else self.state_dim


# --------------------------------------------------------------------------
# Parameter initialisation
# --------------------------------------------------------------------------


def _dense_init(key, fan_in, fan_out):
    lim = 1.0 / math.sqrt(fan_in)
    kw, kb = jax.random.split(key)
    w = jax.random.uniform(kw, (fan_in, fan_out), jnp.float32, -lim, lim)
    b = jax.random.uniform(kb, (fan_out,), jnp.float32, -lim, lim)
    return w, b


def init_actor_params(spec: AlgoSpec, key):
    """Actor parameter pytree (attention + eps-net/MLP + variance head)."""
    params = {}
    keys = jax.random.split(key, 8)
    if spec.use_attention:
        lim = 1.0 / math.sqrt(3)
        params["att_we"] = jax.random.uniform(keys[0], (3, D_MODEL), jnp.float32, -lim, lim)
        lim = 1.0 / math.sqrt(D_MODEL)
        params["att_wq"] = jax.random.uniform(keys[1], (D_MODEL, D_MODEL), jnp.float32, -lim, lim)
        params["att_wk"] = jax.random.uniform(keys[2], (D_MODEL, D_MODEL), jnp.float32, -lim, lim)
        params["att_wv"] = jax.random.uniform(keys[3], (D_MODEL, D_MODEL), jnp.float32, -lim, lim)
        params["att_wo"] = jax.random.uniform(keys[4], (D_MODEL, 1), jnp.float32, -lim, lim)
    a_dim = spec.action_dim
    if spec.use_diffusion:
        c_in = a_dim + TIME_DIM + spec.feature_dim
    else:
        c_in = spec.feature_dim
    w1, b1 = _dense_init(keys[5], c_in, HIDDEN)
    w2, b2 = _dense_init(keys[6], HIDDEN, HIDDEN)
    w3, b3 = _dense_init(keys[7], HIDDEN, a_dim)
    params.update(mlp_w1=w1, mlp_b1=b1, mlp_w2=w2, mlp_b2=b2, mlp_w3=w3, mlp_b3=b3)
    # Variance head: mean -> log sigma (paper: "passing the mean through an
    # additional linear layer").
    kv = jax.random.split(keys[7])[0]
    wv, bv = _dense_init(kv, a_dim, a_dim)
    params["var_w"] = wv
    params["var_b"] = bv - 1.0  # start with small sigma
    return params


def init_critic_params(spec: AlgoSpec, key):
    """Q(s, a) critic (or V(s) for PPO): 256x256 MLP on [state, action]."""
    in_dim = spec.state_dim + (0 if spec.name == "ppo" else spec.action_dim)
    k1, k2, k3 = jax.random.split(key, 3)
    w1, b1 = _dense_init(k1, in_dim, HIDDEN)
    w2, b2 = _dense_init(k2, HIDDEN, HIDDEN)
    w3, b3 = _dense_init(k3, HIDDEN, 1)
    return dict(mlp_w1=w1, mlp_b1=b1, mlp_w2=w2, mlp_b2=b2, mlp_w3=w3, mlp_b3=b3)


# --------------------------------------------------------------------------
# Forward passes
# --------------------------------------------------------------------------


def _time_embedding(t_index, steps):
    """Sinusoidal TIME_DIM-dim embedding of diffusion step i in [1, T]."""
    half = TIME_DIM // 2
    freqs = jnp.exp(jnp.arange(half) * (-math.log(10000.0) / max(half - 1, 1)))
    ang = (t_index / max(steps, 1)) * freqs * steps
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])


def extract_features(spec: AlgoSpec, params, state):
    """f_s from a batch of flat states (B, 3N)."""
    b = state.shape[0]
    if not spec.use_attention:
        return state
    n = spec.n_cols
    # Eq. 6 layout is row-major 3xN; tokens are columns -> (B, N, 3).
    tokens = state.reshape(b, 3, n).transpose(0, 2, 1)
    return attention_feature_batched(
        tokens,
        params["att_we"],
        params["att_wq"],
        params["att_wk"],
        params["att_wv"],
        params["att_wo"],
    )


def _trunk(params, z):
    return denoiser_mlp(
        z,
        params["mlp_w1"],
        params["mlp_b1"],
        params["mlp_w2"],
        params["mlp_b2"],
        params["mlp_w3"],
        params["mlp_b3"],
    )


def _diffusion_schedule(steps):
    betas = jnp.linspace(1e-4, 0.2, steps, dtype=jnp.float32)
    alphas = 1.0 - betas
    abar = jnp.cumprod(alphas)
    return betas, alphas, abar


def actor_mean(spec: AlgoSpec, params, state, chain_noise):
    """Action mean x_0 (B, A).

    Diffusion path (Eqs. 10-12): start from x_T = chain_noise[:, -1], run T
    reverse steps; per-step posterior noise comes from chain_noise[:, i].
    MLP path: tanh(MLP(f_s)).
    """
    feats = extract_features(spec, params, state)
    if not spec.use_diffusion:
        return jnp.tanh(_trunk(params, feats))
    t_steps = spec.denoise_steps
    betas, alphas, abar = _diffusion_schedule(t_steps)
    x = chain_noise[:, t_steps, :]  # x_T ~ N(0, I)
    for i in range(t_steps - 1, -1, -1):
        temb = _time_embedding(jnp.float32(i + 1), t_steps)
        temb_b = jnp.broadcast_to(temb, (x.shape[0], TIME_DIM))
        z = jnp.concatenate([x, temb_b, feats], axis=-1)
        eps = _trunk(params, z)
        mu = (x - betas[i] * eps / jnp.sqrt(1.0 - abar[i])) / jnp.sqrt(alphas[i])
        if i > 0:
            abar_prev = abar[i - 1]
            sigma = jnp.sqrt(betas[i] * (1.0 - abar_prev) / (1.0 - abar[i]))
            x = mu + sigma * chain_noise[:, i, :]
        else:
            x = mu
    return jnp.tanh(x)


def actor_dist(spec: AlgoSpec, params, state, chain_noise):
    """(mean, log_sigma) of the Gaussian action distribution (Eq. 13)."""
    mean = actor_mean(spec, params, state, chain_noise)
    log_sigma = jnp.clip(
        mean @ params["var_w"] + params["var_b"], LOG_SIG_MIN, LOG_SIG_MAX
    )
    return mean, log_sigma


def actor_sample(spec: AlgoSpec, params, state, chain_noise, expl_noise):
    """Reparameterised sample a = clip(mean + sigma*eps) plus entropy."""
    mean, log_sigma = actor_dist(spec, params, state, chain_noise)
    sigma = jnp.exp(log_sigma)
    action = jnp.clip(mean + sigma * expl_noise, -1.0, 1.0)
    # Eq. 14: H = 1/2 sum log(2 pi e sigma^2).
    entropy = 0.5 * jnp.sum(
        jnp.log(2.0 * math.pi * math.e) + 2.0 * log_sigma, axis=-1
    )
    return action, mean, log_sigma, entropy


def critic_q(params, state, action):
    z = jnp.concatenate([state, action], axis=-1)
    return _trunk(params, z)[:, 0]


def critic_v(params, state):
    return _trunk(params, state)[:, 0]


def gaussian_logp(mean, log_sigma, action):
    sigma = jnp.exp(log_sigma)
    z = (action - mean) / sigma
    return jnp.sum(
        -0.5 * z * z - log_sigma - 0.5 * math.log(2.0 * math.pi), axis=-1
    )


# --------------------------------------------------------------------------
# In-graph Adam (Table VIII: Adam, lr 3e-4, weight decay 1e-4)
# --------------------------------------------------------------------------


def adam_update(flat_params, flat_grad, m, v, t, lr, weight_decay):
    """One Adam step over flat vectors; returns (params', m', v')."""
    b1, b2, eps = 0.9, 0.999, 1e-8
    g = flat_grad + weight_decay * flat_params
    m = b1 * m + (1.0 - b1) * g
    v = b2 * v + (1.0 - b2) * g * g
    mhat = m / (1.0 - b1**t)
    vhat = v / (1.0 - b2**t)
    return flat_params - lr * mhat / (jnp.sqrt(vhat) + eps), m, v


# --------------------------------------------------------------------------
# SAC family (EAT / EAT-A / EAT-D / EAT-DA): act + train step
# --------------------------------------------------------------------------


def build_sac(spec: AlgoSpec):
    """Build pure flat-I/O `act` and `train` functions plus metadata."""
    key = jax.random.PRNGKey(hash((spec.name, spec.n_cols)) % (2**31))
    ka, kc1, kc2 = jax.random.split(key, 3)
    actor0 = init_actor_params(spec, ka)
    critic10 = init_critic_params(spec, kc1)
    critic20 = init_critic_params(spec, kc2)
    actor_flat0, unravel_actor = ravel_pytree(actor0)
    critic1_flat0, unravel_critic = ravel_pytree(critic10)
    critic2_flat0, _ = ravel_pytree(critic20)

    # Non-diffusion variants (EAT-D / EAT-DA) never read the chain noise;
    # XLA prunes unused parameters at lowering, so their AOT signatures
    # must omit it entirely (manifest chain_steps = 0 tells rust).
    t_chain = spec.denoise_steps + 1 if spec.use_diffusion else 0
    a_dim = spec.action_dim
    dummy_chain1 = jnp.zeros((1, 1, a_dim), jnp.float32)

    def act_diffusion(actor_flat, state, chain_noise, expl_noise):
        """Single-state action (Algorithm 1 lines 4-12).

        state: (S,), chain_noise: (T+1, A), expl_noise: (A,).
        Returns (action, mean, log_sigma), each (A,).
        """
        p = unravel_actor(actor_flat)
        action, mean, log_sigma, _ = actor_sample(
            spec, p, state[None, :], chain_noise[None, :, :], expl_noise[None, :]
        )
        return action[0], mean[0], log_sigma[0]

    def act_mlp(actor_flat, state, expl_noise):
        p = unravel_actor(actor_flat)
        action, mean, log_sigma, _ = actor_sample(
            spec, p, state[None, :], dummy_chain1, expl_noise[None, :]
        )
        return action[0], mean[0], log_sigma[0]

    act = act_diffusion if spec.use_diffusion else act_mlp

    def train_core(
        actor_flat,
        c1_flat,
        c2_flat,
        c1t_flat,
        c2t_flat,
        m_a,
        v_a,
        m_c1,
        v_c1,
        m_c2,
        v_c2,
        t,
        s,
        a,
        r,
        s2,
        done,
        chain_s,
        chain_s2,
        expl_s,
        expl_s2,
    ):
        """One full SAC update (Algorithm 2 lines 19-22) as a single graph.

        Shapes: s/s2 (B,S); a (B,A); r/done (B,); chain_* (B,T+1,A);
        expl_* (B,A); t scalar step count (float32, >= 1).
        """
        tq = t + 1.0

        # ---- critic update (Eqs. 19-20) --------------------------------
        def critic_loss_fn(c1f, c2f):
            p_a = unravel_actor(actor_flat)
            a2, _, _, _ = actor_sample(spec, p_a, s2, chain_s2, expl_s2)
            q1t = critic_q(unravel_critic(c1t_flat), s2, a2)
            q2t = critic_q(unravel_critic(c2t_flat), s2, a2)
            qt = jnp.minimum(q1t, q2t)  # Eq. 18 on targets
            y = r + spec.gamma * (1.0 - done) * qt  # Eq. 20
            y = jax.lax.stop_gradient(y)
            q1 = critic_q(unravel_critic(c1f), s, a)
            q2 = critic_q(unravel_critic(c2f), s, a)
            loss = jnp.mean((y - q1) ** 2) + jnp.mean((y - q2) ** 2)
            return loss, (jnp.mean(q1), jnp.mean(y))

        (critic_loss, (mean_q, _)), (g_c1, g_c2) = jax.value_and_grad(
            critic_loss_fn, argnums=(0, 1), has_aux=True
        )(c1_flat, c2_flat)
        c1_new, m_c1, v_c1 = adam_update(
            c1_flat, g_c1, m_c1, v_c1, tq, spec.lr_critic, spec.weight_decay
        )
        c2_new, m_c2, v_c2 = adam_update(
            c2_flat, g_c2, m_c2, v_c2, tq, spec.lr_critic, spec.weight_decay
        )

        # ---- actor update (Eqs. 15-17) ----------------------------------
        def actor_loss_fn(af):
            p = unravel_actor(af)
            a_pi, _, _, entropy = actor_sample(spec, p, s, chain_s, expl_s)
            q1 = critic_q(unravel_critic(c1_new), s, a_pi)
            q2 = critic_q(unravel_critic(c2_new), s, a_pi)
            q = jnp.minimum(q1, q2)
            loss = -jnp.mean(q + spec.entropy_alpha * entropy)
            return loss, jnp.mean(entropy)

        (actor_loss, entropy), g_a = jax.value_and_grad(actor_loss_fn, has_aux=True)(
            actor_flat
        )
        actor_new, m_a, v_a = adam_update(
            actor_flat, g_a, m_a, v_a, tq, spec.lr_actor, spec.weight_decay
        )

        # ---- soft target update (Eq. 22) ---------------------------------
        tau = spec.soft_tau
        c1t_new = tau * c1_new + (1.0 - tau) * c1t_flat
        c2t_new = tau * c2_new + (1.0 - tau) * c2t_flat

        return (
            actor_new,
            c1_new,
            c2_new,
            c1t_new,
            c2t_new,
            m_a,
            v_a,
            m_c1,
            v_c1,
            m_c2,
            v_c2,
            tq,
            actor_loss,
            critic_loss,
            mean_q,
            entropy,
        )

    if spec.use_diffusion:
        train = train_core
    else:

        def train(*args):
            """Chain-free signature: same as train_core minus chain_s/s2."""
            (head, tail) = (args[:17], args[17:])
            b = args[12].shape[0]
            dummy = jnp.zeros((b, 1, a_dim), jnp.float32)
            return train_core(*head, dummy, dummy, *tail)

    return dict(
        spec=spec,
        act=act,
        train=train,
        actor_flat0=actor_flat0,
        critic1_flat0=critic1_flat0,
        critic2_flat0=critic2_flat0,
        chain_shape=(t_chain, a_dim),
    )


# --------------------------------------------------------------------------
# PPO baseline
# --------------------------------------------------------------------------


def build_ppo(spec: AlgoSpec):
    """PPO act + train step. GAE advantages/returns come from rust."""
    assert spec.name == "ppo"
    key = jax.random.PRNGKey(hash(("ppo", spec.n_cols)) % (2**31))
    ka, kc = jax.random.split(key)
    actor0 = init_actor_params(spec, ka)
    critic0 = init_critic_params(spec, kc)
    actor_flat0, unravel_actor = ravel_pytree(actor0)
    critic_flat0, unravel_critic = ravel_pytree(critic0)
    dummy_chain = jnp.zeros((1, 1, spec.action_dim), jnp.float32)

    def act(actor_flat, critic_flat, state, expl_noise):
        """Returns (action, logp, value) for one state."""
        p = unravel_actor(actor_flat)
        s = state[None, :]
        mean, log_sigma = actor_dist(spec, p, s, dummy_chain)
        sigma = jnp.exp(log_sigma)
        action = jnp.clip(mean + sigma * expl_noise[None, :], -1.0, 1.0)
        logp = gaussian_logp(mean, log_sigma, action)
        value = critic_v(unravel_critic(critic_flat), s)
        return action[0], logp[0], value[0]

    def train(
        actor_flat,
        critic_flat,
        m_a,
        v_a,
        m_c,
        v_c,
        t,
        s,
        a,
        old_logp,
        adv,
        ret,
    ):
        """One PPO epoch over a minibatch (clip objective + value MSE)."""
        tq = t + 1.0
        dummy = jnp.zeros((s.shape[0], 1, spec.action_dim), jnp.float32)

        def actor_loss_fn(af):
            p = unravel_actor(af)
            mean, log_sigma = actor_dist(spec, p, s, dummy)
            logp = gaussian_logp(mean, log_sigma, a)
            ratio = jnp.exp(logp - old_logp)
            adv_n = (adv - jnp.mean(adv)) / (jnp.std(adv) + 1e-8)
            unclipped = ratio * adv_n
            clipped = jnp.clip(ratio, 1.0 - spec.ppo_clip, 1.0 + spec.ppo_clip) * adv_n
            entropy = jnp.mean(
                0.5 * jnp.sum(jnp.log(2.0 * math.pi * math.e) + 2.0 * log_sigma, axis=-1)
            )
            pi_loss = -jnp.mean(jnp.minimum(unclipped, clipped))
            loss = pi_loss - spec.ppo_entropy_coef * entropy
            approx_kl = jnp.mean(old_logp - logp)
            return loss, (pi_loss, entropy, approx_kl)

        (_, (pi_loss, entropy, approx_kl)), g_a = jax.value_and_grad(
            actor_loss_fn, has_aux=True
        )(actor_flat)
        actor_new, m_a, v_a = adam_update(
            actor_flat, g_a, m_a, v_a, tq, spec.lr_actor, spec.weight_decay
        )

        def value_loss_fn(cf):
            v = critic_v(unravel_critic(cf), s)
            return spec.ppo_value_coef * jnp.mean((v - ret) ** 2)

        v_loss, g_c = jax.value_and_grad(value_loss_fn)(critic_flat)
        critic_new, m_c, v_c = adam_update(
            critic_flat, g_c, m_c, v_c, tq, spec.lr_critic, spec.weight_decay
        )

        return (
            actor_new,
            critic_new,
            m_a,
            v_a,
            m_c,
            v_c,
            tq,
            pi_loss,
            v_loss,
            entropy,
            approx_kl,
        )

    return dict(
        spec=spec,
        act=act,
        train=train,
        actor_flat0=actor_flat0,
        critic_flat0=critic_flat0,
    )


def make_spec(
    name: str,
    num_servers: int,
    queue_window: int,
    denoise_steps: int = 10,
    batch_size: int = 128,
    gamma: float = 0.95,
    entropy_alpha: float = 0.05,
    soft_tau: float = 0.005,
    lr: float = 3e-4,
    weight_decay: float = 1e-4,
) -> AlgoSpec:
    return AlgoSpec(
        name=name,
        num_servers=num_servers,
        queue_window=queue_window,
        denoise_steps=denoise_steps,
        batch_size=batch_size,
        gamma=gamma,
        entropy_alpha=entropy_alpha,
        soft_tau=soft_tau,
        lr_actor=lr,
        lr_critic=lr,
        weight_decay=weight_decay,
    )
