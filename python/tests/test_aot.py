"""AOT bridge tests: lowering produces parseable HLO text with the right
parameter signature, and the manifest agrees with the dumped init files."""

import json
import os
import subprocess
import sys
import tempfile

import numpy as np
import pytest


@pytest.fixture(scope="module")
def quick_artifacts():
    """Lower the quick subset (eat + ppo on n8l8) into a temp dir once."""
    tmp = tempfile.mkdtemp(prefix="eat_aot_test_")
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", tmp, "--quick", "--batch", "8"],
        check=True,
        cwd=root,
    )
    return tmp


def test_manifest_structure(quick_artifacts):
    with open(os.path.join(quick_artifacts, "manifest.json")) as f:
        m = json.load(f)
    assert m["version"] == 1
    assert m["batch_size"] == 8
    assert "eat_n8l8_act" in m["entries"]
    assert "eat_n8l8_train" in m["entries"]
    assert "ppo_n8l8_act" in m["entries"]
    p = m["params"]["eat_n8l8"]
    assert p["state_dim"] == 48
    assert p["action_dim"] == 10
    assert p["chain_steps"] == 11  # T+1 for T=10


def test_hlo_text_parsable_and_has_entry(quick_artifacts):
    path = os.path.join(quick_artifacts, "eat_n8l8_act.hlo.txt")
    with open(path) as f:
        text = f.read()
    assert "ENTRY" in text and "HloModule" in text
    # act has 4 params: actor, state, chain, expl.
    assert text.count("parameter(") >= 4


def test_init_files_match_manifest_lengths(quick_artifacts):
    with open(os.path.join(quick_artifacts, "manifest.json")) as f:
        m = json.load(f)
    p = m["params"]["eat_n8l8"]
    actor = np.fromfile(
        os.path.join(quick_artifacts, p["init_files"]["actor"]), dtype="<f4"
    )
    assert actor.shape[0] == p["actor_len"]
    assert np.all(np.isfinite(actor))
    # Sane init scale: bounded uniform, not all zeros.
    assert 0.0 < np.abs(actor).max() < 2.0
    c1 = np.fromfile(
        os.path.join(quick_artifacts, p["init_files"]["critic1"]), dtype="<f4"
    )
    c2 = np.fromfile(
        os.path.join(quick_artifacts, p["init_files"]["critic2"]), dtype="<f4"
    )
    assert c1.shape[0] == c2.shape[0] == p["critic_len"]
    # Double critics start from different initialisations.
    assert not np.array_equal(c1, c2)


def test_train_entry_io_counts(quick_artifacts):
    with open(os.path.join(quick_artifacts, "manifest.json")) as f:
        m = json.load(f)
    e = m["entries"]["eat_n8l8_train"]
    assert len(e["inputs"]) == 21  # diffusion variant with chain noises
    assert len(e["outputs"]) == 16
    names = [t["name"] for t in e["inputs"]]
    assert names[:5] == ["actor", "critic1", "critic2", "critic1_target", "critic2_target"]
    ppo = m["entries"]["ppo_n8l8_train"]
    assert len(ppo["inputs"]) == 12
    assert len(ppo["outputs"]) == 11


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
