"""L1 correctness: Pallas kernels vs pure-jnp oracles.

The CORE correctness signal for the compiled stack: the same kernels that
lower into every act/train HLO are checked here against ref.py, including
hypothesis sweeps over shapes and dtypes and gradient checks through the
custom_vjp hooks.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Optional dependency: without it the whole module skips (instead of a
# collection error that aborts the entire test run).
pytest.importorskip("hypothesis", reason="hypothesis not installed; property sweeps skipped")
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import attention_feature, attention_feature_batched
from compile.kernels.denoise import denoiser_mlp
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)


def _attn_weights(key, d=16):
    ks = jax.random.split(key, 5)
    return (
        jax.random.normal(ks[0], (3, d)) * 0.5,
        jax.random.normal(ks[1], (d, d)) * 0.5,
        jax.random.normal(ks[2], (d, d)) * 0.5,
        jax.random.normal(ks[3], (d, d)) * 0.5,
        jax.random.normal(ks[4], (d, 1)) * 0.5,
    )


class TestAttentionKernel:
    def test_matches_ref_single(self):
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (16, 3))
        w = _attn_weights(key)
        out = attention_feature(x, *w)
        expected = ref.attention_feature_ref(x, *w)
        np.testing.assert_allclose(out, expected, rtol=1e-5, atol=1e-5)

    @settings(max_examples=25, deadline=None)
    @given(
        b=st.integers(1, 8),
        n=st.integers(2, 24),
        d=st.sampled_from([4, 8, 16, 32]),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref_batched_shapes(self, b, n, d, seed):
        key = jax.random.PRNGKey(seed)
        x = jax.random.normal(key, (b, n, 3))
        w = _attn_weights(key, d)
        out = attention_feature_batched(x, *w)
        assert out.shape == (b, n)
        expected = jnp.stack(
            [ref.attention_feature_ref(x[i], *w) for i in range(b)]
        )
        np.testing.assert_allclose(out, expected, rtol=2e-4, atol=2e-4)

    def test_softmax_stability_large_logits(self):
        # Large-magnitude tokens must not overflow the softmax.
        key = jax.random.PRNGKey(1)
        x = jax.random.normal(key, (2, 8, 3)) * 100.0
        w = _attn_weights(key)
        out = attention_feature_batched(x, *w)
        assert bool(jnp.all(jnp.isfinite(out)))

    def test_gradients_match_ref(self):
        # custom_vjp backward == autodiff through the reference.
        key = jax.random.PRNGKey(2)
        x = jax.random.normal(key, (2, 6, 3))
        w = _attn_weights(key, 8)

        def loss_kernel(*args):
            return jnp.sum(attention_feature_batched(*args) ** 2)

        def loss_ref(*args):
            outs = jnp.stack(
                [ref.attention_feature_ref(args[0][i], *args[1:]) for i in range(2)]
            )
            return jnp.sum(outs**2)

        g_kernel = jax.grad(loss_kernel, argnums=(0, 1, 2))(x, *w)
        g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(x, *w)
        for gk, gr in zip(g_kernel, g_ref):
            np.testing.assert_allclose(gk, gr, rtol=1e-4, atol=1e-4)

    def test_permutation_equivariance(self):
        # Self-attention with no positional encoding: permuting tokens
        # permutes the features identically.
        key = jax.random.PRNGKey(3)
        x = jax.random.normal(key, (1, 10, 3))
        w = _attn_weights(key)
        perm = jnp.array([3, 1, 4, 0, 2, 9, 8, 7, 5, 6])
        out = attention_feature_batched(x, *w)[0]
        out_p = attention_feature_batched(x[:, perm, :], *w)[0]
        np.testing.assert_allclose(out[perm], out_p, rtol=1e-5, atol=1e-5)


class TestDenoiserKernel:
    def test_matches_ref(self):
        key = jax.random.PRNGKey(4)
        ks = jax.random.split(key, 6)
        z = jax.random.normal(ks[0], (8, 40))
        w1 = jax.random.normal(ks[1], (40, 64)) * 0.2
        b1 = jax.random.normal(ks[2], (64,)) * 0.1
        w2 = jax.random.normal(ks[3], (64, 64)) * 0.2
        b2 = jnp.zeros((64,))
        w3 = jax.random.normal(ks[4], (64, 10)) * 0.2
        b3 = jnp.zeros((10,))
        out = denoiser_mlp(z, w1, b1, w2, b2, w3, b3)
        expected = ref.denoiser_mlp_ref(z, w1, b1, w2, b2, w3, b3)
        np.testing.assert_allclose(out, expected, rtol=1e-5, atol=1e-6)

    @settings(max_examples=20, deadline=None)
    @given(
        b=st.integers(1, 16),
        c=st.integers(1, 64),
        h=st.sampled_from([8, 32, 256]),
        a=st.integers(1, 12),
        seed=st.integers(0, 2**16),
    )
    def test_shape_sweep(self, b, c, h, a, seed):
        key = jax.random.PRNGKey(seed)
        ks = jax.random.split(key, 4)
        z = jax.random.normal(ks[0], (b, c))
        w1 = jax.random.normal(ks[1], (c, h)) * 0.1
        w2 = jax.random.normal(ks[2], (h, h)) * 0.1
        w3 = jax.random.normal(ks[3], (h, a)) * 0.1
        zeros = lambda n: jnp.zeros((n,))
        out = denoiser_mlp(z, w1, zeros(h), w2, zeros(h), w3, zeros(a))
        assert out.shape == (b, a)
        expected = ref.denoiser_mlp_ref(z, w1, zeros(h), w2, zeros(h), w3, zeros(a))
        np.testing.assert_allclose(out, expected, rtol=2e-4, atol=2e-4)

    def test_gradients_match_ref(self):
        key = jax.random.PRNGKey(5)
        ks = jax.random.split(key, 4)
        z = jax.random.normal(ks[0], (4, 6))
        w1 = jax.random.normal(ks[1], (6, 8)) * 0.3
        w2 = jax.random.normal(ks[2], (8, 8)) * 0.3
        w3 = jax.random.normal(ks[3], (8, 3)) * 0.3
        zeros = lambda n: jnp.zeros((n,))
        args = (z, w1, zeros(8), w2, zeros(8), w3, zeros(3))
        g_k = jax.grad(lambda *a: jnp.sum(denoiser_mlp(*a) ** 2), argnums=tuple(range(7)))(*args)
        g_r = jax.grad(lambda *a: jnp.sum(ref.denoiser_mlp_ref(*a) ** 2), argnums=tuple(range(7)))(*args)
        for gk, gr in zip(g_k, g_r):
            np.testing.assert_allclose(gk, gr, rtol=1e-4, atol=1e-5)

    def test_mish_matches_definition(self):
        x = jnp.linspace(-10, 10, 101)
        expected = x * jnp.tanh(jnp.log1p(jnp.exp(-jnp.abs(x))) + jnp.maximum(x, 0.0))
        np.testing.assert_allclose(ref.mish(x), expected, rtol=1e-5, atol=1e-6)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
