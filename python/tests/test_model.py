"""L2 correctness: network shapes, diffusion chain, SAC/PPO train steps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


def small_spec(name="eat", batch=8):
    return model.make_spec(name, 4, 4, denoise_steps=4, batch_size=batch)


def make_batch(spec, key, batch):
    ks = jax.random.split(key, 8)
    B, S, A = batch, spec.state_dim, spec.action_dim
    T1 = spec.denoise_steps + 1
    return dict(
        s=jax.random.uniform(ks[0], (B, S)),
        a=jnp.clip(jax.random.normal(ks[1], (B, A)), -1, 1),
        r=jax.random.uniform(ks[2], (B,)),
        s2=jax.random.uniform(ks[3], (B, S)),
        done=jnp.zeros((B,)),
        chain_s=jax.random.normal(ks[4], (B, T1, A)),
        chain_s2=jax.random.normal(ks[5], (B, T1, A)),
        expl_s=jax.random.normal(ks[6], (B, A)) * 0.1,
        expl_s2=jax.random.normal(ks[7], (B, A)) * 0.1,
    )


class TestSpecs:
    def test_dims(self):
        spec = model.make_spec("eat", 8, 8)
        assert spec.state_dim == 48
        assert spec.action_dim == 10
        assert spec.n_cols == 16
        assert spec.use_attention and spec.use_diffusion

    def test_variant_flags(self):
        assert not model.make_spec("eat_a", 4, 4).use_attention
        assert model.make_spec("eat_a", 4, 4).use_diffusion
        assert model.make_spec("eat_d", 4, 4).use_attention
        assert not model.make_spec("eat_d", 4, 4).use_diffusion
        da = model.make_spec("eat_da", 4, 4)
        assert not da.use_attention and not da.use_diffusion

    def test_feature_dim(self):
        assert model.make_spec("eat", 4, 4).feature_dim == 8      # N
        assert model.make_spec("eat_a", 4, 4).feature_dim == 24   # 3N


class TestActor:
    @pytest.mark.parametrize("name", ["eat", "eat_a", "eat_d", "eat_da"])
    def test_action_bounded_and_finite(self, name):
        spec = small_spec(name)
        built = model.build_sac(spec)
        A = spec.action_dim
        T1 = spec.denoise_steps + 1
        state = jnp.full((spec.state_dim,), 0.3)
        if spec.use_diffusion:
            chain = jax.random.normal(jax.random.PRNGKey(0), (T1, A))
            action, mean, log_sigma = built["act"](built["actor_flat0"], state, chain, jnp.zeros((A,)))
        else:
            action, mean, log_sigma = built["act"](built["actor_flat0"], state, jnp.zeros((A,)))
        assert action.shape == (A,)
        assert bool(jnp.all(jnp.abs(action) <= 1.0))
        assert bool(jnp.all(jnp.abs(mean) <= 1.0))  # tanh-bounded
        assert bool(jnp.all((log_sigma >= model.LOG_SIG_MIN) & (log_sigma <= model.LOG_SIG_MAX)))

    def test_diffusion_chain_noise_changes_action(self):
        spec = small_spec("eat")
        built = model.build_sac(spec)
        A, T1 = spec.action_dim, spec.denoise_steps + 1
        state = jnp.full((spec.state_dim,), 0.3)
        a1, _, _ = built["act"](built["actor_flat0"], state, jnp.zeros((T1, A)), jnp.zeros((A,)))
        chain2 = jax.random.normal(jax.random.PRNGKey(1), (T1, A)) * 2.0
        a2, _, _ = built["act"](built["actor_flat0"], state, chain2, jnp.zeros((A,)))
        assert not np.allclose(a1, a2)

    def test_entropy_formula(self):
        # H = 0.5 * sum(log(2*pi*e*sigma^2)) for diagonal Gaussians.
        spec = small_spec("eat_da")
        p = model.init_actor_params(spec, jax.random.PRNGKey(0))
        s = jnp.zeros((2, spec.state_dim))
        _, mean, log_sigma, entropy = model.actor_sample(
            spec, p, s, jnp.zeros((2, 1, spec.action_dim)), jnp.zeros((2, spec.action_dim))
        )
        expected = 0.5 * jnp.sum(jnp.log(2 * jnp.pi * jnp.e) + 2 * log_sigma, axis=-1)
        np.testing.assert_allclose(entropy, expected, rtol=1e-5)

    def test_gaussian_logp_matches_scipy_form(self):
        mean = jnp.array([[0.0, 1.0]])
        log_sigma = jnp.array([[0.0, jnp.log(2.0)]])
        action = jnp.array([[1.0, 1.0]])
        lp = model.gaussian_logp(mean, log_sigma, action)
        # N(1; 0,1): -0.5 - 0.5*log(2pi); N(1; 1,2): -log(2) - 0.5*log(2pi)
        expected = (-0.5 - 0.5 * np.log(2 * np.pi)) + (-np.log(2.0) - 0.5 * np.log(2 * np.pi))
        np.testing.assert_allclose(lp[0], expected, rtol=1e-5)


class TestAdam:
    def test_first_step_direction_and_magnitude(self):
        p = jnp.array([1.0, -2.0])
        g = jnp.array([0.5, -0.5])
        p1, m, v = model.adam_update(p, g, jnp.zeros(2), jnp.zeros(2), 1.0, 1e-3, 0.0)
        # First Adam step has magnitude ~lr in the gradient direction.
        np.testing.assert_allclose(p1, p - 1e-3 * jnp.sign(g), rtol=1e-3)
        assert m.shape == (2,) and v.shape == (2,)

    def test_weight_decay_shrinks_params(self):
        p = jnp.array([10.0])
        g = jnp.array([0.0])
        p1, _, _ = model.adam_update(p, g, jnp.zeros(1), jnp.zeros(1), 1.0, 1e-2, 0.1)
        assert float(p1[0]) < 10.0


class TestSacTrain:
    @pytest.mark.parametrize("name", ["eat", "eat_da"])
    def test_losses_finite_and_critic_improves(self, name):
        spec = small_spec(name)
        built = model.build_sac(spec)
        B = spec.batch_size
        batch = make_batch(spec, jax.random.PRNGKey(7), B)
        P = built["actor_flat0"].shape[0]
        C = built["critic1_flat0"].shape[0]
        zeros = jnp.zeros
        state = [
            built["actor_flat0"], built["critic1_flat0"], built["critic2_flat0"],
            built["critic1_flat0"], built["critic2_flat0"],
            zeros((P,)), zeros((P,)), zeros((C,)), zeros((C,)), zeros((C,)), zeros((C,)),
            jnp.float32(0.0),
        ]
        args = list(batch.values())
        if not spec.use_diffusion:
            args = [a for k, a in batch.items() if not k.startswith("chain")]
        train = jax.jit(built["train"])
        out = train(*state, *args)
        first_critic = float(out[13])
        assert np.isfinite(float(out[12])) and np.isfinite(first_critic)
        for _ in range(15):
            out = train(*list(out[:12]), *args)
        assert float(out[13]) < first_critic, "critic loss should drop on a fixed batch"

    def test_target_network_soft_update(self):
        spec = small_spec("eat_da")
        built = model.build_sac(spec)
        B = spec.batch_size
        batch = make_batch(spec, jax.random.PRNGKey(8), B)
        args = [a for k, a in batch.items() if not k.startswith("chain")]
        P = built["actor_flat0"].shape[0]
        C = built["critic1_flat0"].shape[0]
        zeros = jnp.zeros
        out = jax.jit(built["train"])(
            built["actor_flat0"], built["critic1_flat0"], built["critic2_flat0"],
            built["critic1_flat0"], built["critic2_flat0"],
            zeros((P,)), zeros((P,)), zeros((C,)), zeros((C,)), zeros((C,)), zeros((C,)),
            jnp.float32(0.0), *args,
        )
        c1_new, c1t_new = out[1], out[3]
        # Soft update: c1t' = tau*c1' + (1-tau)*c1t0.
        expected = spec.soft_tau * c1_new + (1 - spec.soft_tau) * built["critic1_flat0"]
        np.testing.assert_allclose(c1t_new, expected, rtol=1e-4, atol=1e-6)


class TestPpo:
    def test_act_and_train(self):
        spec = model.make_spec("ppo", 4, 4, batch_size=8)
        built = model.build_ppo(spec)
        A, S = spec.action_dim, spec.state_dim
        action, logp, value = built["act"](
            built["actor_flat0"], built["critic_flat0"], jnp.zeros((S,)), jnp.zeros((A,))
        )
        assert action.shape == (A,)
        assert np.isfinite(float(logp)) and np.isfinite(float(value))
        B = spec.batch_size
        P = built["actor_flat0"].shape[0]
        C = built["critic_flat0"].shape[0]
        zeros = jnp.zeros
        key = jax.random.PRNGKey(9)
        out = jax.jit(built["train"])(
            built["actor_flat0"], built["critic_flat0"],
            zeros((P,)), zeros((P,)), zeros((C,)), zeros((C,)), jnp.float32(0.0),
            jax.random.uniform(key, (B, S)),
            jnp.clip(jax.random.normal(key, (B, A)), -1, 1),
            zeros((B,)) - 5.0,
            jax.random.normal(key, (B,)),
            jax.random.normal(key, (B,)),
        )
        assert len(out) == 11
        for x in out[7:]:
            assert np.isfinite(float(x))

    def test_value_loss_drops_on_fixed_batch(self):
        spec = model.make_spec("ppo", 4, 4, batch_size=8)
        built = model.build_ppo(spec)
        B, S, A = spec.batch_size, spec.state_dim, spec.action_dim
        key = jax.random.PRNGKey(10)
        P = built["actor_flat0"].shape[0]
        C = built["critic_flat0"].shape[0]
        zeros = jnp.zeros
        s = jax.random.uniform(key, (B, S))
        a = jnp.clip(jax.random.normal(key, (B, A)), -1, 1)
        old_logp = zeros((B,)) - 5.0
        adv = jax.random.normal(key, (B,))
        ret = jnp.ones((B,)) * 3.0
        train = jax.jit(built["train"])
        state = [built["actor_flat0"], built["critic_flat0"],
                 zeros((P,)), zeros((P,)), zeros((C,)), zeros((C,)), jnp.float32(0.0)]
        out = train(*state, s, a, old_logp, adv, ret)
        first = float(out[8])
        for _ in range(20):
            out = train(*list(out[:7]), s, a, old_logp, adv, ret)
        assert float(out[8]) < first


class TestDiffusionSchedule:
    def test_abar_monotone_decreasing(self):
        betas, alphas, abar = model._diffusion_schedule(10)
        assert betas.shape == (10,)
        assert bool(jnp.all(betas > 0)) and bool(jnp.all(betas < 1))
        assert bool(jnp.all(jnp.diff(abar) < 0))


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
